//! Integration: the threaded parameter server end to end (native engine).

use dmlps::config::{Consistency, Preset};
use dmlps::data::ExperimentData;
use dmlps::ps::{FaultSpec, RunOptions};

fn tiny_cfg(steps: usize, workers: usize) -> dmlps::config::ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = steps;
    cfg.cluster.workers = workers;
    cfg
}

/// mnist_small-style config: enough signal to learn in seconds, enough
/// compute per step that parameter refreshes keep pace with workers.
fn mid_cfg(steps: usize, workers: usize) -> dmlps::config::ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.name = "ps_mid".into();
    cfg.dataset.dim = 64;
    cfg.dataset.n_classes = 10;
    cfg.dataset.separation = 4.0;
    cfg.dataset.n_train = 2_000;
    cfg.dataset.n_test = 1_000;
    cfg.dataset.n_similar = 5_000;
    cfg.dataset.n_dissimilar = 5_000;
    cfg.dataset.n_test_pairs = 2_000;
    cfg.model.k = 48;
    cfg.model.init_scale = 0.2;
    cfg.optim.batch_sim = 16;
    cfg.optim.batch_dis = 16;
    cfg.optim.lr = 0.3;
    cfg.optim.steps = steps;
    cfg.cluster.workers = workers;
    cfg.artifact_variant = None;
    cfg
}

#[test]
fn training_converges_and_beats_euclidean() {
    let cfg = mid_cfg(1500, 2);
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    assert_eq!(r.applied_updates, 3000);
    let mut eng = dmlps::dml::NativeEngine::new();
    let ap = dmlps::cli::driver::ap_of_l(&mut eng, &r.l, &data).unwrap();
    let eu = dmlps::cli::driver::ap_euclidean(&data);
    assert!(ap > eu + 0.1, "ap={ap} euclid={eu}");
}

#[test]
fn every_worker_completes_its_budget() {
    for workers in [1usize, 3, 5] {
        let cfg = tiny_cfg(50, workers);
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        let r = dmlps::cli::driver::train_distributed(
            &cfg, &data, "native", &RunOptions::default()).unwrap();
        assert_eq!(r.worker_stats.len(), workers);
        for ws in &r.worker_stats {
            assert_eq!(ws.steps_done, 50, "worker {}", ws.id);
        }
        assert_eq!(r.applied_updates, (50 * workers) as u64);
    }
}

#[test]
fn consistency_models_all_complete() {
    for consistency in [Consistency::Asp, Consistency::Bsp,
                        Consistency::Ssp { staleness: 2 }] {
        let mut cfg = tiny_cfg(60, 3);
        cfg.cluster.consistency = consistency;
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        let r = dmlps::cli::driver::train_distributed(
            &cfg, &data, "native", &RunOptions::default()).unwrap();
        assert_eq!(r.applied_updates, 180, "{consistency:?}");
        if consistency == Consistency::Bsp {
            // BSP workers must have blocked at the barrier at least once
            let wait: f64 = r.worker_stats.iter().map(|w| w.wait_s).sum();
            assert!(wait >= 0.0);
        }
    }
}

#[test]
fn survives_gradient_drops() {
    // 20% of gradient messages dropped: training still completes and
    // still learns (the dropped updates are simply lost work, as in a
    // lossy datacenter transport).
    let cfg = tiny_cfg(400, 2);
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.2,
            drop_param_prob: 0.0,
            latency: std::time::Duration::ZERO,
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts).unwrap();
    let dropped: u64 =
        r.worker_stats.iter().map(|w| w.grads_dropped).sum();
    assert!(dropped > 50, "fault injection inactive: {dropped}");
    assert!(r.applied_updates < 800);
    let first = r.curve.points.first().unwrap().objective;
    let best = r.curve.points.iter().map(|p| p.objective)
        .fold(f64::INFINITY, f64::min);
    assert!(best < first * 0.95, "no progress under drops: \
            first={first} best={best}");
}

#[test]
fn survives_param_drops_and_latency() {
    let cfg = tiny_cfg(200, 2);
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.0,
            drop_param_prob: 0.5,
            latency: std::time::Duration::from_micros(100),
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts).unwrap();
    assert_eq!(r.applied_updates, 400);
}
