//! Integration: the threaded (and sharded) parameter server end to end,
//! native engine — the PS-protocol suite CI runs under a hard timeout.

use dmlps::config::{
    CompressionConfig, CompressionMode, Consistency, Preset,
};
use dmlps::data::{partition_pairs, ExperimentData, MinibatchIter};
use dmlps::dml::{DmlProblem, Engine, LrSchedule, MinibatchRef, NativeEngine};
use dmlps::linalg::Mat;
use dmlps::ps::{FaultSpec, RunOptions};
use dmlps::util::rng::Pcg32;

fn tiny_cfg(steps: usize, workers: usize) -> dmlps::config::ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = steps;
    cfg.cluster.workers = workers;
    cfg
}

/// mnist_small-style config: enough signal to learn in seconds, enough
/// compute per step that parameter refreshes keep pace with workers.
fn mid_cfg(steps: usize, workers: usize) -> dmlps::config::ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.name = "ps_mid".into();
    cfg.dataset.dim = 64;
    cfg.dataset.n_classes = 10;
    cfg.dataset.separation = 4.0;
    cfg.dataset.n_train = 2_000;
    cfg.dataset.n_test = 1_000;
    cfg.dataset.n_similar = 5_000;
    cfg.dataset.n_dissimilar = 5_000;
    cfg.dataset.n_test_pairs = 2_000;
    cfg.model.k = 48;
    cfg.model.init_scale = 0.2;
    cfg.optim.batch_sim = 16;
    cfg.optim.batch_dis = 16;
    cfg.optim.lr = 0.3;
    cfg.optim.steps = steps;
    cfg.cluster.workers = workers;
    cfg.artifact_variant = None;
    cfg
}

#[test]
fn training_converges_and_beats_euclidean() {
    let cfg = mid_cfg(1500, 2);
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    assert_eq!(r.applied_updates, 3000);
    let mut eng = dmlps::dml::NativeEngine::new();
    let ap = dmlps::cli::driver::ap_of_l(&mut eng, &r.l, &data).unwrap();
    let eu = dmlps::cli::driver::ap_euclidean(&data);
    assert!(ap > eu + 0.1, "ap={ap} euclid={eu}");
}

#[test]
fn every_worker_completes_its_budget() {
    for workers in [1usize, 3, 5] {
        let cfg = tiny_cfg(50, workers);
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        let r = dmlps::cli::driver::train_distributed(
            &cfg, &data, "native", &RunOptions::default()).unwrap();
        assert_eq!(r.worker_stats.len(), workers);
        for ws in &r.worker_stats {
            assert_eq!(ws.steps_done, 50, "worker {}", ws.id);
        }
        assert_eq!(r.applied_updates, (50 * workers) as u64);
    }
}

#[test]
fn consistency_models_all_complete() {
    for consistency in [Consistency::Asp, Consistency::Bsp,
                        Consistency::Ssp { staleness: 2 }] {
        let mut cfg = tiny_cfg(60, 3);
        cfg.cluster.consistency = consistency;
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        let r = dmlps::cli::driver::train_distributed(
            &cfg, &data, "native", &RunOptions::default()).unwrap();
        assert_eq!(r.applied_updates, 180, "{consistency:?}");
        if consistency == Consistency::Bsp {
            // BSP workers must have blocked at the barrier at least once
            let wait: f64 = r.worker_stats.iter().map(|w| w.wait_s).sum();
            assert!(wait >= 0.0);
        }
    }
}

#[test]
fn survives_gradient_drops() {
    // 20% of gradient messages dropped: training still completes and
    // still learns (the dropped updates are simply lost work, as in a
    // lossy datacenter transport).
    let cfg = tiny_cfg(400, 2);
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.2,
            drop_param_prob: 0.0,
            latency: std::time::Duration::ZERO,
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts).unwrap();
    let dropped: u64 =
        r.worker_stats.iter().map(|w| w.grads_dropped).sum();
    assert!(dropped > 50, "fault injection inactive: {dropped}");
    assert!(r.applied_updates < 800);
    let first = r.curve.points.first().unwrap().objective;
    let best = r.curve.points.iter().map(|p| p.objective)
        .fold(f64::INFINITY, f64::min);
    assert!(best < first * 0.95, "no progress under drops: \
            first={first} best={best}");
}

#[test]
fn survives_param_drops_and_latency() {
    let cfg = tiny_cfg(200, 2);
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.0,
            drop_param_prob: 0.5,
            latency: std::time::Duration::from_micros(100),
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts).unwrap();
    assert_eq!(r.applied_updates, 400);
}

// ---------------------------------------------------------------------
// Sharded-server protocol suite
// ---------------------------------------------------------------------

#[test]
fn sharded_server_matches_step_budget() {
    for shards in [1usize, 2, 4] {
        let mut cfg = tiny_cfg(40, 2);
        cfg.cluster.server_shards = shards;
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        let r = dmlps::cli::driver::train_distributed(
            &cfg, &data, "native", &RunOptions::default()).unwrap();
        assert_eq!(r.server_shards, shards);
        assert_eq!(r.applied_updates, 80, "shards={shards}");
        assert_eq!(r.slice_updates, 80 * shards as u64);
        for ws in &r.worker_stats {
            assert_eq!(ws.steps_done, 40, "worker {}", ws.id);
            assert_eq!(ws.grads_sent, 40);
            assert_eq!(ws.grads_dropped, 0);
        }
    }
}

#[test]
fn sharded_training_converges() {
    let mut cfg = mid_cfg(800, 2);
    cfg.cluster.server_shards = 4;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    assert_eq!(r.applied_updates, 1600);
    let first = r.curve.points.first().unwrap().objective;
    let best = r.curve.points.iter().map(|p| p.objective)
        .fold(f64::INFINITY, f64::min);
    assert!(best < first * 0.9,
            "sharded run made no progress: first={first} best={best}");
}

#[test]
fn shards_clamped_to_row_count() {
    // tiny has k = 8; asking for 32 shards must clamp, not crash
    let mut cfg = tiny_cfg(30, 2);
    cfg.cluster.server_shards = 32;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    assert_eq!(r.server_shards, 8);
    assert_eq!(r.applied_updates, 60);
}

#[test]
fn fault_injection_accounting_identity() {
    // Sharded training under drops on both directions plus delivery
    // latency. The accounting identity must hold exactly: one fate per
    // step, so per-worker sent + dropped = steps, and the server can
    // never apply more than was sent.
    let mut cfg = tiny_cfg(400, 2);
    cfg.cluster.server_shards = 3;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.2,
            drop_param_prob: 0.15,
            latency: std::time::Duration::from_micros(200),
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts).unwrap();
    let mut total_sent = 0u64;
    let mut total_dropped = 0u64;
    for ws in &r.worker_stats {
        assert_eq!(
            ws.grads_sent + ws.grads_dropped,
            ws.steps_done,
            "worker {}: sent {} + dropped {} != steps {}",
            ws.id, ws.grads_sent, ws.grads_dropped, ws.steps_done
        );
        assert_eq!(ws.steps_done, 400);
        total_sent += ws.grads_sent;
        total_dropped += ws.grads_dropped;
    }
    assert!(total_dropped > 50, "fault injection inactive");
    assert!(r.applied_updates <= total_sent,
            "applied {} > sent {total_sent}", r.applied_updates);
    // slices of one step share one fate: slice count is exact
    assert_eq!(r.slice_updates, r.applied_updates * 3);
    // and training still learns despite the losses
    let first = r.curve.points.first().unwrap().objective;
    let best = r.curve.points.iter().map(|p| p.objective)
        .fold(f64::INFINITY, f64::min);
    assert!(best < first * 0.95,
            "no progress under faults: first={first} best={best}");
}

#[test]
fn lossy_transport_requires_asp() {
    // BSP/SSP gates wait on clocks that a dropped, never-retransmitted
    // update can stall forever; the run must refuse up front rather
    // than deadlock.
    let mut cfg = tiny_cfg(10, 2);
    cfg.cluster.consistency = Consistency::Bsp;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.1,
            drop_param_prob: 0.0,
            latency: std::time::Duration::ZERO,
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts);
    assert!(r.is_err(), "BSP + drops must be rejected, not hang");
    // latency alone is fine: messages are delayed, never lost
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.0,
            drop_param_prob: 0.0,
            latency: std::time::Duration::from_micros(100),
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts).unwrap();
    assert_eq!(r.applied_updates, 20);
}

#[test]
fn ssp_staleness_bounded_by_min_shard_clock() {
    // SSP(s): no worker's step may run more than s ahead of the
    // min-over-shards server clock, ever.
    for staleness in [1usize, 3] {
        let mut cfg = tiny_cfg(80, 2);
        cfg.cluster.server_shards = 2;
        cfg.cluster.consistency = Consistency::Ssp { staleness };
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        let r = dmlps::cli::driver::train_distributed(
            &cfg, &data, "native", &RunOptions::default()).unwrap();
        assert_eq!(r.applied_updates, 160);
        for ws in &r.worker_stats {
            assert!(
                ws.max_staleness <= staleness as u64,
                "SSP({staleness}) violated: worker {} observed \
                 staleness {}",
                ws.id, ws.max_staleness
            );
        }
    }
}

#[test]
fn bsp_degenerates_to_lockstep() {
    let mut cfg = tiny_cfg(60, 2);
    cfg.cluster.server_shards = 2;
    cfg.cluster.consistency = Consistency::Bsp;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    assert_eq!(r.applied_updates, 120);
    for ws in &r.worker_stats {
        assert_eq!(
            ws.max_staleness, 0,
            "BSP must be lockstep; worker {} observed staleness {}",
            ws.id, ws.max_staleness
        );
    }
}

/// Sequential SGD mirroring a 1-worker run's exact sampling and the
/// server's exact apply arithmetic (lr_scale = 1/P = 1) — the golden
/// anchor the distributed protocol is pinned against.
fn sequential_reference(
    cfg: &dmlps::config::ExperimentConfig,
    data: &ExperimentData,
) -> Mat {
    let problem = DmlProblem::new(
        cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    let mut l = problem.init_l(cfg.model.init_scale, cfg.seed);
    let shards =
        partition_pairs(&data.pairs, 1, cfg.seed ^ 0x5A4D).unwrap();
    let mut iter = MinibatchIter::new(
        &data.train,
        &shards[0].pairs,
        cfg.optim.batch_sim,
        cfg.optim.batch_dis,
        Pcg32::with_stream(cfg.seed ^ (1u64 << 16), 0x3000),
    );
    let lr = LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay);
    let mut eng = NativeEngine::new();
    let mut g = Mat::zeros(cfg.model.k, cfg.dataset.dim);
    for step in 0..cfg.optim.steps {
        iter.next_batch();
        let batch = MinibatchRef::new(
            &iter.ds_buf,
            &iter.dd_buf,
            cfg.optim.batch_sim,
            cfg.optim.batch_dis,
            cfg.dataset.dim,
        );
        eng.loss_grad(&l, &batch, cfg.optim.lambda, &mut g).unwrap();
        let lr_t = lr.at(step) * 1.0f32;
        for (a, gv) in l.data.iter_mut().zip(&g.data) {
            *a -= lr_t * gv;
        }
    }
    l
}

#[test]
fn single_worker_single_shard_bsp_matches_sequential_sgd() {
    // 1 worker + 1 shard + BSP + perfect transport is sequential SGD in
    // disguise: every step computes on the server's L (the gate admits
    // step t only after the server applied and broadcast grad t−1), so
    // the final L must be *bit-identical* to a sequential loop with the
    // same seed, minibatch stream, and lr schedule.
    let mut cfg = tiny_cfg(60, 1);
    cfg.cluster.server_shards = 1;
    cfg.cluster.consistency = Consistency::Bsp;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    let l = sequential_reference(&cfg, &data);
    assert_eq!(r.applied_updates, 60);
    assert_eq!(
        r.l.data, l.data,
        "distributed(1 worker, 1 shard, BSP) must equal sequential SGD \
         bit for bit"
    );
}

#[test]
fn last_loss_is_surfaced() {
    let cfg = mid_cfg(120, 2);
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    assert!(
        r.last_loss.is_finite() && r.last_loss > 0.0,
        "last_loss not populated: {}",
        r.last_loss
    );
    // the hinge+pull objective shrinks as training progresses, and the
    // telemetry should reflect a real (not sentinel) value
    let first = r.curve.points.first().unwrap().objective;
    assert!(
        (r.last_loss as f64) < first * 10.0,
        "last_loss {} implausible vs initial objective {first}",
        r.last_loss
    );
}

// ---------------------------------------------------------------------
// Compressed wire-protocol suite
// ---------------------------------------------------------------------

#[test]
fn compression_none_is_bit_identical_to_sequential_anchor() {
    // The explicit mode=none config must reproduce the PR-2/PR-3 dense
    // protocol bit for bit — same golden anchor as the test above, now
    // routed through the compression-aware encode/decode paths.
    let mut cfg = tiny_cfg(60, 1);
    cfg.cluster.server_shards = 1;
    cfg.cluster.consistency = Consistency::Bsp;
    cfg.cluster.compression = CompressionConfig {
        mode: CompressionMode::None,
        keep: 0.5, // must be inert under mode=none
    };
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    let l = sequential_reference(&cfg, &data);
    assert_eq!(
        r.l.data, l.data,
        "mode=none must stay bit-identical to sequential SGD"
    );
}

#[test]
fn topk_int8_error_feedback_tracks_dense_final_loss() {
    // Error-feedback contract, end to end: at keep=0.25 the compressed
    // run moves ~4-8× fewer bytes yet must land within a small ε of the
    // dense final objective. 1 worker + BSP makes both runs
    // deterministic, so this is a stable regression, not a flake.
    let mut dense_cfg = mid_cfg(400, 1);
    dense_cfg.cluster.server_shards = 2;
    dense_cfg.cluster.consistency = Consistency::Bsp;
    let mut topk_cfg = dense_cfg.clone();
    topk_cfg.cluster.compression = CompressionConfig {
        mode: CompressionMode::TopKInt8,
        keep: 0.25,
    };
    let data = ExperimentData::generate(&dense_cfg.dataset,
                                        dense_cfg.seed);
    let rd = dmlps::cli::driver::train_distributed(
        &dense_cfg, &data, "native", &RunOptions::default()).unwrap();
    let rt = dmlps::cli::driver::train_distributed(
        &topk_cfg, &data, "native", &RunOptions::default()).unwrap();
    assert_eq!(rd.applied_updates, 400);
    assert_eq!(rt.applied_updates, 400);

    let first = rd.curve.points.first().unwrap().objective;
    let dense_final = rd.curve.points.last().unwrap().objective;
    let topk_final = rt.curve.points.last().unwrap().objective;
    assert!(dense_final < first * 0.5, "dense run failed to learn");
    assert!(topk_final < first * 0.5, "compressed run failed to learn");
    assert!(
        (topk_final - dense_final).abs() <= 0.10 * first,
        "compressed final {topk_final} drifted from dense \
         {dense_final} (initial {first})"
    );

    // and the byte reduction that motivated the ε: ≥ 4× on the wire
    let dense_bytes: u64 =
        rd.worker_stats.iter().map(|w| w.grad_bytes_sent).sum();
    let topk_bytes: u64 =
        rt.worker_stats.iter().map(|w| w.grad_bytes_sent).sum();
    assert!(
        topk_bytes * 4 <= dense_bytes,
        "expected ≥4× reduction: {topk_bytes} vs {dense_bytes}"
    );
}

#[test]
fn fault_injection_accounting_identity_holds_with_compression() {
    // The PR-2 identity re-verified with the compressed protocol under
    // drops on both directions plus delivery latency: encoding must not
    // change what a "message" is — one fate per step, sent + dropped =
    // steps, and the server can never fold more than was sent.
    let mut cfg = tiny_cfg(400, 2);
    cfg.cluster.server_shards = 3;
    cfg.cluster.compression = CompressionConfig {
        mode: CompressionMode::TopKInt8,
        keep: 0.25,
    };
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let opts = RunOptions {
        faults: FaultSpec {
            drop_grad_prob: 0.2,
            drop_param_prob: 0.15,
            latency: std::time::Duration::from_micros(200),
        },
        ..Default::default()
    };
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &opts).unwrap();
    let mut total_sent = 0u64;
    let mut total_dropped = 0u64;
    let mut total_grad_bytes = 0u64;
    for ws in &r.worker_stats {
        assert_eq!(
            ws.grads_sent + ws.grads_dropped,
            ws.steps_done,
            "worker {}: sent {} + dropped {} != steps {}",
            ws.id, ws.grads_sent, ws.grads_dropped, ws.steps_done
        );
        assert_eq!(ws.steps_done, 400);
        assert!(ws.grad_bytes_sent > 0, "worker {} byte telemetry",
                ws.id);
        total_sent += ws.grads_sent;
        total_dropped += ws.grads_dropped;
        total_grad_bytes += ws.grad_bytes_sent;
    }
    assert!(total_dropped > 50, "fault injection inactive");
    assert!(r.applied_updates <= total_sent,
            "applied {} > sent {total_sent}", r.applied_updates);
    assert_eq!(r.slice_updates, r.applied_updates * 3);
    // bytes obey the same drop gate as messages: the server can only
    // have received what workers' transports accepted
    assert!(
        r.grad_bytes_received <= total_grad_bytes,
        "server folded {} bytes but transports accepted only {}",
        r.grad_bytes_received, total_grad_bytes
    );
    // compression is actually on: well under half the dense volume
    let dense_step_bytes =
        (cfg.model.k * cfg.dataset.dim * 4) as u64;
    assert!(
        total_grad_bytes < total_sent * dense_step_bytes / 2,
        "wire not compressed: {total_grad_bytes}"
    );
    // and training still learns despite drops + compression
    let first = r.curve.points.first().unwrap().objective;
    let best = r.curve.points.iter().map(|p| p.objective)
        .fold(f64::INFINITY, f64::min);
    assert!(best < first * 0.95,
            "no progress under faults: first={first} best={best}");
}

#[test]
fn dense_byte_accounting_matches_shardplan_arithmetic() {
    // mode=none over a perfect transport: every byte counter must equal
    // the ShardPlan slice-size arithmetic exactly — the unit anchor that
    // keeps BENCH_wire.json comparable with BENCH_ps.json.
    let (steps, workers, shards) = (30usize, 2usize, 3usize);
    let mut cfg = tiny_cfg(steps, workers);
    cfg.cluster.server_shards = shards;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    let plan = dmlps::ps::ShardPlan::new(
        cfg.model.k, cfg.dataset.dim, shards);
    // Σ over shards of 4·len(s) = 4·k·d per step, regardless of S
    let step_bytes: u64 =
        (0..plan.shards()).map(|s| 4 * plan.len(s) as u64).sum();
    assert_eq!(step_bytes, (4 * cfg.model.k * cfg.dataset.dim) as u64);
    for ws in &r.worker_stats {
        assert_eq!(
            ws.grad_bytes_sent,
            steps as u64 * step_bytes,
            "worker {}: dense bytes must be steps × 4kd exactly",
            ws.id
        );
    }
    assert_eq!(
        r.grad_bytes_received,
        (steps * workers) as u64 * step_bytes,
        "server-side fold bytes must match what workers shipped"
    );

    // single shard: every param message is the full 4·k·d payload, so
    // both ends' counters are exact multiples of the message count
    let mut cfg1 = tiny_cfg(steps, 1);
    cfg1.cluster.server_shards = 1;
    let r1 = dmlps::cli::driver::train_distributed(
        &cfg1, &data, "native", &RunOptions::default()).unwrap();
    let full = (4 * cfg1.model.k * cfg1.dataset.dim) as u64;
    assert_eq!(r1.param_bytes_sent, r1.param_msgs * full);
    let ws = &r1.worker_stats[0];
    assert_eq!(
        ws.param_bytes_received,
        ws.params_received * full,
        "worker param bytes must be params_received × 4kd"
    );
    assert!(
        ws.param_bytes_received <= r1.param_bytes_sent,
        "worker cannot receive more than the server shipped"
    );
}

#[test]
fn compressed_run_meets_four_x_wire_budget_end_to_end() {
    let (steps, workers) = (50usize, 2usize);
    let mut cfg = tiny_cfg(steps, workers);
    cfg.cluster.server_shards = 2;
    cfg.cluster.compression = CompressionConfig {
        mode: CompressionMode::TopKInt8,
        keep: 0.25,
    };
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    // perfect transport: the server folds exactly what workers shipped
    let sent_bytes: u64 =
        r.worker_stats.iter().map(|w| w.grad_bytes_sent).sum();
    assert_eq!(r.grad_bytes_received, sent_bytes);
    let dense_total =
        ((steps * workers) * 4 * cfg.model.k * cfg.dataset.dim) as u64;
    assert!(
        sent_bytes * 4 <= dense_total,
        "topk_int8@0.25 under-compressed: {sent_bytes} of {dense_total}"
    );
    // int8 param broadcasts: every slice is exactly 4 (scale) +
    // k·d/S (one i8 per element) bytes here (k divides evenly by S)
    assert!(r.param_msgs > 0);
    let int8_slice_bytes =
        4 + (cfg.model.k * cfg.dataset.dim / r.server_shards) as u64;
    assert_eq!(
        r.param_bytes_sent,
        r.param_msgs * int8_slice_bytes,
        "param slices not int8-quantized"
    );
}

#[test]
fn sharded_consistency_models_all_complete() {
    for consistency in [Consistency::Asp, Consistency::Bsp,
                        Consistency::Ssp { staleness: 2 }] {
        let mut cfg = tiny_cfg(50, 3);
        cfg.cluster.server_shards = 4;
        cfg.cluster.consistency = consistency;
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        let r = dmlps::cli::driver::train_distributed(
            &cfg, &data, "native", &RunOptions::default()).unwrap();
        assert_eq!(r.applied_updates, 150, "{consistency:?}");
        assert_eq!(r.slice_updates, 600, "{consistency:?}");
    }
}

/// Regression for the SSP-gate lost wakeup: shard clocks must be stored
/// and the condvar notified *under* the gate mutex. When they are not,
/// a BSP worker checking the gate between the store and the notify
/// misses the wakeup and falls back on the 50 ms recheck timeout —
/// inflating `wait_s` by up to ~50 ms per barrier round. With prompt
/// wakeups, total barrier wait at tiny scale stays far below the bound.
#[test]
fn bsp_barrier_wakeups_are_prompt() {
    let steps = 150;
    let mut cfg = tiny_cfg(steps, 2);
    cfg.cluster.consistency = Consistency::Bsp;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default()).unwrap();
    // generous bound: 25 ms/step of legitimate wait is an order of
    // magnitude above healthy tiny-preset barriers, and half the 50 ms
    // per-round cost the lost-wakeup bug reintroduces
    let bound = steps as f64 * 0.025;
    for ws in &r.worker_stats {
        assert_eq!(ws.steps_done, steps as u64, "worker {}", ws.id);
        assert!(
            ws.wait_s < bound,
            "worker {} waited {:.3}s over {steps} BSP steps \
             (bound {bound:.2}s) — lost-wakeup regression",
            ws.id, ws.wait_s
        );
    }
}
