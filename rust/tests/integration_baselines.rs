//! Integration: the four §5.4 methods run end-to-end on one shared
//! dataset and produce comparable, sane metrics.

use dmlps::baselines::{Itml, ItmlConfig, Kiss, KissConfig, LearnedMetric,
                       Xing2002, Xing2002Config};
use dmlps::data::{ExperimentData, PairSet};
use dmlps::config::Preset;

fn data() -> ExperimentData {
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.n_train = 600;
    cfg.dataset.n_test = 300;
    cfg.dataset.n_similar = 800;
    cfg.dataset.n_dissimilar = 800;
    cfg.dataset.n_test_pairs = 400;
    ExperimentData::generate(&cfg.dataset, 7)
}

fn check(name: &str, m: &LearnedMetric, data: &ExperimentData) -> f64 {
    let (sim, dis) = m.score(&data.test, &data.test_pairs);
    assert_eq!(sim.len(), data.test_pairs.similar.len(), "{name}");
    assert!(sim.iter().chain(dis.iter()).all(|v| v.is_finite()),
            "{name}: non-finite distances");
    let ap = dmlps::eval::average_precision(&sim, &dis);
    assert!((0.0..=1.0).contains(&ap), "{name}: ap={ap}");
    ap
}

#[test]
fn all_methods_produce_valid_metrics() {
    let data = data();
    let eu = check("euclid", &LearnedMetric::Euclidean, &data);

    let (x, trace) = Xing2002::new(Xing2002Config {
        iters: 8, ..Default::default()
    }).fit_traced(&data.train, &data.pairs, &data.test, &data.test_pairs);
    assert!(!trace.is_empty());
    check("xing2002", &x, &data);

    let (i, trace) = Itml::new(ItmlConfig {
        sweeps: 1, ..Default::default()
    }).fit_traced(&data.train, &data.pairs, &data.test, &data.test_pairs);
    assert!(!trace.is_empty());
    let itml_ap = check("itml", &i, &data);
    assert!(itml_ap > eu - 0.15, "ITML collapsed: {itml_ap} vs {eu}");

    let k = Kiss::new(KissConfig { pca_dim: 16, ..Default::default() })
        .fit(&data.train, &data.pairs);
    check("kiss", &k, &data);
}

#[test]
fn traces_are_time_ordered() {
    let data = data();
    let (_, trace) = Itml::new(ItmlConfig {
        sweeps: 1, probe_every_pairs: 100, ..Default::default()
    }).fit_traced(&data.train, &data.pairs, &data.test, &data.test_pairs);
    for w in trace.windows(2) {
        assert!(w[1].0 >= w[0].0);
    }
}

#[test]
fn kiss_handles_duplicate_heavy_pairsets() {
    // degenerate-ish inputs: few distinct samples, many repeated pairs
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.n_train = 60;
    cfg.dataset.n_similar = 500;
    cfg.dataset.n_dissimilar = 500;
    let data = ExperimentData::generate(&cfg.dataset, 9);
    assert!(PairSet::sample(
        &data.train, 10, 10,
        &mut dmlps::util::rng::Pcg32::new(1)).check_labels(&data.train));
    let k = Kiss::new(KissConfig { pca_dim: 8, ..Default::default() })
        .fit(&data.train, &data.pairs);
    check("kiss-degenerate", &k, &data);
}
