//! Property + golden tests for the streaming pair pipeline — the
//! data-layer suite CI runs in release under a hard timeout.
//!
//! Covers the `(seed, w, t)` determinism contract of the implicit
//! sampler (multiset invariance over worker count / batch size / draw
//! chunking; disjoint + jointly exhaustive worker index spaces), the
//! streaming analogue of `PairSet::check_labels`, the scenario knobs
//! (label noise, class imbalance), the golden streaming ≡ sequential
//! SGD equivalence, and the `pairs < workers` clean-error regression.

use std::sync::Arc;

use dmlps::config::{Consistency, PairMode, Preset};
use dmlps::data::{
    Dataset, ExperimentData, ImplicitPairSampler, MinibatchIter,
    SyntheticSpec, WorkerPairs,
};
use dmlps::dml::{DmlProblem, Engine, LrSchedule, MinibatchRef, NativeEngine};
use dmlps::linalg::Mat;
use dmlps::ps::RunOptions;
use dmlps::util::check::forall;
use dmlps::util::rng::Pcg32;

fn tiny_ds(seed: u64) -> Arc<Dataset> {
    Arc::new(SyntheticSpec::tiny().generate(seed))
}

fn sampler(
    ds: &Arc<Dataset>,
    seed: u64,
    worker: usize,
    stride: usize,
) -> ImplicitPairSampler {
    ImplicitPairSampler::new(ds.clone(), seed, worker, stride, 0.0, 0.0)
        .unwrap()
}

// ---------------------------------------------------------------------
// Determinism contract
// ---------------------------------------------------------------------

#[test]
fn prop_multiset_invariant_to_worker_count_and_chunking() {
    forall(
        "same (seed, total draws) ⇒ same pair multiset for any P / chunking",
        10,
        |g| {
            let ds = tiny_ds(g.case_seed);
            let seed = g.case_seed ^ 0xABCD;
            let per = g.usize_in(2, 16);
            let total = 12 * per; // divisible by every P below
            // reference: a single worker drawing everything in order
            let mut r = sampler(&ds, seed, 0, 1);
            let mut want_sim: Vec<(u32, u32)> = (0..total)
                .map(|_| {
                    let p = r.next_similar();
                    (p.i, p.j)
                })
                .collect();
            let mut want_dis: Vec<(u32, u32)> = (0..total)
                .map(|_| {
                    let p = r.next_dissimilar();
                    (p.i, p.j)
                })
                .collect();
            want_sim.sort_unstable();
            want_dis.sort_unstable();
            for workers in [2usize, 3, 4, 6] {
                let n = total / workers;
                let mut got_sim = Vec::with_capacity(total);
                let mut got_dis = Vec::with_capacity(total);
                for w in 0..workers {
                    let mut s = sampler(&ds, seed, w, workers);
                    // draw in randomly sized interleaved chunks: the
                    // multiset must not depend on batch size or on how
                    // sim/dis draws interleave
                    let (mut ns, mut nd) = (0usize, 0usize);
                    while ns < n || nd < n {
                        for _ in 0..g.usize_in(1, 5).min(n - ns) {
                            let p = s.next_similar();
                            got_sim.push((p.i, p.j));
                            ns += 1;
                        }
                        for _ in 0..g.usize_in(1, 5).min(n - nd) {
                            let p = s.next_dissimilar();
                            got_dis.push((p.i, p.j));
                            nd += 1;
                        }
                    }
                }
                got_sim.sort_unstable();
                got_dis.sort_unstable();
                assert_eq!(got_sim, want_sim, "P={workers} similar");
                assert_eq!(got_dis, want_dis, "P={workers} dissimilar");
            }
        },
    );
}

#[test]
fn prop_worker_index_spaces_are_disjoint_and_exhaustive() {
    forall(
        "worker w owns indices ≡ w (mod P), pure in (seed, t)",
        12,
        |g| {
            let ds = tiny_ds(g.case_seed ^ 7);
            let seed = g.case_seed;
            let workers = g.usize_in(1, 6);
            let n = g.usize_in(1, 24);
            // oracle sampler used only through its pure (seed, t) fns
            let oracle = sampler(&ds, seed, 0, 1);
            let mut seen: Vec<u64> = Vec::with_capacity(workers * n);
            for w in 0..workers {
                let mut s = sampler(&ds, seed, w, workers);
                for k in 0..n {
                    let t = s.cursors().0;
                    assert_eq!(
                        t,
                        (w + k * workers) as u64,
                        "worker {w} of {workers}, draw {k}"
                    );
                    assert_eq!(s.next_similar(), oracle.similar_at(t));
                    seen.push(t);
                }
            }
            // disjoint + jointly exhaustive: the union of the worker
            // index spaces is exactly 0..n*P, each index once
            seen.sort_unstable();
            let want: Vec<u64> = (0..(workers * n) as u64).collect();
            assert_eq!(seen, want);
        },
    );
}

// ---------------------------------------------------------------------
// Label semantics (streaming analogue of PairSet::check_labels)
// ---------------------------------------------------------------------

#[test]
fn prop_streamed_pairs_respect_labels_without_noise() {
    forall("clean streams: similar matched, dissimilar mismatched", 10, |g| {
        let mut spec = SyntheticSpec::tiny();
        spec.n_classes = g.usize_in(2, 8);
        let ds = Arc::new(spec.generate(g.case_seed));
        let imbalance = *g.pick(&[0.0f32, 0.5, 2.0]);
        let mut s = ImplicitPairSampler::new(
            ds.clone(),
            g.case_seed ^ 0x11,
            0,
            1,
            0.0,
            imbalance,
        )
        .unwrap();
        for _ in 0..300 {
            let p = s.next_similar();
            assert_ne!(p.i, p.j, "self pair");
            assert_eq!(
                ds.labels[p.i as usize], ds.labels[p.j as usize],
                "similar pair with mismatched labels (imb={imbalance})"
            );
            let q = s.next_dissimilar();
            assert_ne!(
                ds.labels[q.i as usize], ds.labels[q.j as usize],
                "dissimilar pair with matched labels (imb={imbalance})"
            );
        }
    });
}

#[test]
fn label_noise_flips_the_expected_fraction() {
    let ds = tiny_ds(3);
    let noise = 0.3f32;
    let mut s =
        ImplicitPairSampler::new(ds.clone(), 21, 0, 1, noise, 0.0).unwrap();
    let n = 4000;
    let mut sim_flipped = 0usize;
    let mut dis_flipped = 0usize;
    for _ in 0..n {
        let p = s.next_similar();
        if ds.labels[p.i as usize] != ds.labels[p.j as usize] {
            sim_flipped += 1;
        }
        let q = s.next_dissimilar();
        if ds.labels[q.i as usize] == ds.labels[q.j as usize] {
            dis_flipped += 1;
        }
    }
    let fs = sim_flipped as f64 / n as f64;
    let fd = dis_flipped as f64 / n as f64;
    // binomial sd at n=4000, p=0.3 is ~0.007; ±0.05 is >6 sigma
    assert!((fs - 0.3).abs() < 0.05, "similar flip rate {fs}");
    assert!((fd - 0.3).abs() < 0.05, "dissimilar flip rate {fd}");
}

#[test]
fn imbalance_skews_class_draw_frequencies() {
    let ds = tiny_ds(4); // 4 well-populated classes
    let share_of_head = |imbalance: f32| -> f64 {
        let mut s =
            ImplicitPairSampler::new(ds.clone(), 33, 0, 1, 0.0, imbalance)
                .unwrap();
        let n = 4000;
        let head = (0..n)
            .filter(|_| {
                let p = s.next_similar();
                ds.labels[p.i as usize] == 0
            })
            .count();
        head as f64 / n as f64
    };
    let uniform = share_of_head(0.0);
    assert!((uniform - 0.25).abs() < 0.05, "uniform head share {uniform}");
    // Zipf(2) over 4 classes puts ~0.70 of the mass on the head class
    let skewed = share_of_head(2.0);
    assert!(skewed > 0.5, "skewed head share {skewed}");
}

// ---------------------------------------------------------------------
// Golden equivalence: streaming == sequential SGD, bit for bit
// ---------------------------------------------------------------------

#[test]
fn golden_streaming_bsp_single_worker_matches_sequential_sgd() {
    // 1 worker + 1 server shard + BSP + perfect transport is sequential
    // SGD in disguise (see integration_ps for the materialized twin).
    // Feeding the *same pair sequence* — an identically constructed
    // (seed, w=0, stride=1) implicit sampler — the streaming pipeline
    // must produce a bit-identical L, anchoring the refactor.
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 60;
    cfg.cluster.workers = 1;
    cfg.cluster.server_shards = 1;
    cfg.cluster.consistency = Consistency::Bsp;
    cfg.cluster.pairs.mode = PairMode::Streaming;
    let data = ExperimentData::generate_for(
        &cfg.dataset, PairMode::Streaming, cfg.seed,
    );
    assert!(data.pairs.is_empty(), "streaming mode must not materialize");
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default(),
    )
    .unwrap();

    // sequential reference over the identical pair sequence
    let train = Arc::new(SyntheticSpec::from_config(&cfg.dataset).generate_with(
        &mut Pcg32::with_stream(cfg.seed, 0xDA7A),
        cfg.dataset.n_train,
    ));
    assert_eq!(train.x.data, data.train.x.data, "train regeneration");
    let s = ImplicitPairSampler::new(train.clone(), cfg.seed, 0, 1, 0.0, 0.0)
        .unwrap();
    let mut iter = MinibatchIter::from_stream(
        &train,
        WorkerPairs::Streaming(s)
            .into_stream(Pcg32::with_stream(cfg.seed, 0x3000)),
        cfg.optim.batch_sim,
        cfg.optim.batch_dis,
    );
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    let mut l = problem.init_l(cfg.model.init_scale, cfg.seed);
    let lr = LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay);
    let mut eng = NativeEngine::new();
    let mut g = Mat::zeros(cfg.model.k, cfg.dataset.dim);
    for step in 0..cfg.optim.steps {
        iter.next_batch();
        let batch = MinibatchRef::new(
            &iter.ds_buf,
            &iter.dd_buf,
            cfg.optim.batch_sim,
            cfg.optim.batch_dis,
            cfg.dataset.dim,
        );
        eng.loss_grad(&l, &batch, cfg.optim.lambda, &mut g).unwrap();
        let lr_t = lr.at(step);
        for (a, gv) in l.data.iter_mut().zip(&g.data) {
            *a -= lr_t * gv;
        }
    }
    assert_eq!(r.applied_updates, 60);
    assert_eq!(
        r.l.data, l.data,
        "streaming(1 worker, 1 shard, BSP) must equal sequential SGD \
         bit for bit"
    );
}

// ---------------------------------------------------------------------
// End-to-end streaming behaviour + clean-error regression
// ---------------------------------------------------------------------

#[test]
fn streaming_run_completes_budget_with_zero_pair_bytes() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 50;
    cfg.cluster.workers = 3;
    cfg.cluster.pairs.mode = PairMode::Streaming;
    let data = ExperimentData::generate_for(
        &cfg.dataset, PairMode::Streaming, cfg.seed,
    );
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default(),
    )
    .unwrap();
    assert_eq!(r.applied_updates, 150);
    let per_step = (cfg.optim.batch_sim + cfg.optim.batch_dis) as u64;
    for ws in &r.worker_stats {
        assert_eq!(ws.steps_done, 50, "worker {}", ws.id);
        assert_eq!(ws.pair_bytes, 0, "worker {} stores pairs", ws.id);
        assert_eq!(ws.pairs_drawn, 50 * per_step, "worker {}", ws.id);
    }
    // materialized twin holds its shard in memory
    let mut mcfg = cfg.clone();
    mcfg.cluster.pairs.mode = PairMode::Materialized;
    let mdata = ExperimentData::generate(&mcfg.dataset, mcfg.seed);
    let m = dmlps::cli::driver::train_distributed(
        &mcfg, &mdata, "native", &RunOptions::default(),
    )
    .unwrap();
    assert_eq!(m.applied_updates, 150);
    for ws in &m.worker_stats {
        assert!(ws.pair_bytes > 0, "worker {} shard bytes", ws.id);
    }
}

#[test]
fn streaming_scenario_knobs_train_to_finite_loss() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 40;
    cfg.cluster.workers = 2;
    cfg.cluster.pairs.mode = PairMode::Streaming;
    cfg.cluster.pairs.label_noise = 0.2;
    cfg.cluster.pairs.imbalance = 1.0;
    let data = ExperimentData::generate_for(
        &cfg.dataset, PairMode::Streaming, cfg.seed,
    );
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default(),
    )
    .unwrap();
    assert_eq!(r.applied_updates, 80);
    assert!(r.last_loss.is_finite(), "loss {}", r.last_loss);
    for pt in &r.curve.points {
        assert!(pt.objective.is_finite());
    }
}

#[test]
fn fewer_pairs_than_workers_is_a_clean_error() {
    // regression: partition_pairs used to hard-assert and kill the
    // process from library code; it must surface as a normal error
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.n_similar = 3;
    cfg.dataset.n_dissimilar = 3;
    cfg.optim.steps = 5;
    cfg.cluster.workers = 10;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let err = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default(),
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("fewer pairs than workers"),
        "unexpected error: {err}"
    );
}
