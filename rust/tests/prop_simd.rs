//! SIMD-kernel property suite: the vector backend must agree with the
//! scalar reference within 4 ULP on the tested shapes, and the scalar
//! path must stay **bit-identical** to the pre-SIMD (PR 5) semantics.
//!
//! Backend forcing is process-global, so every test that touches
//! `force_backend` serializes through one mutex and restores auto
//! dispatch on exit (panic included). On builds without `--features
//! simd` (or non-AVX2 CPUs) the forced-SIMD path degrades to scalar and
//! the comparisons hold trivially — the suite is meaningful in both CI
//! legs.
//!
//! ULP methodology: the agreement tests use *positive* inputs, so every
//! accumulation is monotone and the scalar-vs-FMA rounding drift stays
//! well inside 4 ULP of the final value at depths ≤ 63. (With signed
//! inputs, cancellation can make the final value arbitrarily small
//! relative to the partials, and no fixed ULP bound exists — the
//! signed-input case is covered by the looser relative-tolerance test.)

use std::sync::{Mutex, MutexGuard};

use dmlps::dml::{Engine, MinibatchRef, NativeEngine};
use dmlps::linalg::gemm::{gemm_into, KMajor};
use dmlps::linalg::simd::{self, DispatchDecision, KernelBackend};
use dmlps::linalg::{self, Mat};
use dmlps::util::pool::ThreadPool;
use dmlps::util::rng::Pcg32;

/// Serializes backend forcing across the (parallel) tests in this
/// binary; the guard restores auto dispatch when dropped.
static BACKEND_LOCK: Mutex<()> = Mutex::new(());

struct DispatchGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for DispatchGuard {
    fn drop(&mut self) {
        simd::force_backend(None);
    }
}

fn lock_dispatch() -> DispatchGuard {
    let g = BACKEND_LOCK
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    DispatchGuard(g)
}

/// Monotone integer key: |key(a) − key(b)| = ULP steps between a and b.
fn ulp_key(x: f32) -> i64 {
    let b = x.to_bits() as i64;
    if b & 0x8000_0000 != 0 {
        0x8000_0000 - b
    } else {
        b
    }
}

fn ulp_diff(a: f32, b: f32) -> u64 {
    assert!(
        a.is_finite() && b.is_finite(),
        "non-finite kernel output: {a} vs {b}"
    );
    (ulp_key(a) - ulp_key(b)).unsigned_abs()
}

/// Uniform positive values in [0.5, 1.5): monotone accumulation, no
/// cancellation — the regime where the 4-ULP contract is provable.
fn fill_positive(rng: &mut Pcg32, buf: &mut [f32]) {
    for v in buf.iter_mut() {
        *v = rng.f32() + 0.5;
    }
}

/// Small exact integers: every product and partial sum is exactly
/// representable, so scalar and SIMD must agree **bitwise** — a pure
/// functional check of lane/tail indexing.
fn fill_exact(rng: &mut Pcg32, buf: &mut [f32]) {
    for v in buf.iter_mut() {
        *v = rng.below(8) as f32;
    }
}

const ODD_DIMS: [usize; 5] = [1, 3, 7, 17, 63];

fn run_gemm(
    backend: KernelBackend,
    a: &Mat,
    b: &Mat,
    kk: usize,
    m: usize,
    n: usize,
) -> Mat {
    simd::force_backend(Some(backend));
    let mut c = Mat::zeros(m, n);
    gemm_into(
        KMajor::rows_k(&a.data, kk, m),
        KMajor::rows_k(&b.data, kk, n),
        &mut c.data,
        0.0,
        None,
    );
    c
}

#[test]
fn simd_gemm_matches_scalar_within_4_ulp_on_odd_shapes() {
    let _g = lock_dispatch();
    let mut rng = Pcg32::new(41);
    for &kk in &ODD_DIMS {
        for &m in &ODD_DIMS {
            for &n in &ODD_DIMS {
                let mut a = Mat::zeros(kk, m);
                let mut b = Mat::zeros(kk, n);
                fill_positive(&mut rng, &mut a.data);
                fill_positive(&mut rng, &mut b.data);
                let cs = run_gemm(KernelBackend::Scalar, &a, &b, kk, m, n);
                let cv = run_gemm(KernelBackend::Simd, &a, &b, kk, m, n);
                for (i, (&s, &v)) in
                    cs.data.iter().zip(&cv.data).enumerate()
                {
                    let ulp = ulp_diff(s, v);
                    assert!(
                        ulp <= 4,
                        "gemm (kk={kk},m={m},n={n}) elem {i}: \
                         scalar {s} vs simd {v} = {ulp} ULP"
                    );
                }
            }
        }
    }
}

#[test]
fn simd_gemm_is_bitwise_exact_on_integer_inputs() {
    // exact-arithmetic shapes exercise every remainder-tail combination
    // (m % 4, n % 8, kk % KC all nonzero) without rounding noise
    let _g = lock_dispatch();
    let mut rng = Pcg32::new(42);
    for &(kk, m, n) in
        &[(1usize, 1usize, 1usize), (5, 9, 11), (63, 13, 17), (300, 7, 23)]
    {
        let mut a = Mat::zeros(kk, m);
        let mut b = Mat::zeros(kk, n);
        fill_exact(&mut rng, &mut a.data);
        fill_exact(&mut rng, &mut b.data);
        let cs = run_gemm(KernelBackend::Scalar, &a, &b, kk, m, n);
        let cv = run_gemm(KernelBackend::Simd, &a, &b, kk, m, n);
        assert_eq!(
            cs.data, cv.data,
            "exact-integer gemm must be bitwise backend-invariant \
             (kk={kk},m={m},n={n})"
        );
    }
}

#[test]
fn simd_gemm_parallel_is_bit_identical_to_serial() {
    // the SIMD tile must preserve the kernel's cross-thread-count
    // determinism: strips are data-parallel, tiles identical per strip
    let _g = lock_dispatch();
    simd::force_backend(Some(KernelBackend::Simd));
    let mut rng = Pcg32::new(43);
    let (kk, m, n) = (310, 90, 77);
    let mut a = Mat::zeros(kk, m);
    let mut b = Mat::zeros(kk, n);
    rng.fill_gaussian(&mut a.data, 0.0, 1.0);
    rng.fill_gaussian(&mut b.data, 0.0, 1.0);
    let mut serial = Mat::zeros(m, n);
    gemm_into(
        KMajor::rows_k(&a.data, kk, m),
        KMajor::rows_k(&b.data, kk, n),
        &mut serial.data,
        0.0,
        None,
    );
    for threads in [2usize, 3, 4] {
        let pool = ThreadPool::new(threads);
        let mut par = Mat::zeros(m, n);
        gemm_into(
            KMajor::rows_k(&a.data, kk, m),
            KMajor::rows_k(&b.data, kk, n),
            &mut par.data,
            0.0,
            Some(&pool),
        );
        assert_eq!(
            serial.data, par.data,
            "SIMD gemm must stay bit-identical across thread counts \
             ({threads} threads)"
        );
    }
}

#[test]
fn simd_scan_primitives_match_scalar_within_4_ulp() {
    let _g = lock_dispatch();
    let mut rng = Pcg32::new(44);
    // odd lengths + every 8-lane remainder tail, capped at 64 to stay
    // in the provable 4-ULP regime (see module docs)
    for &n in
        &[1usize, 3, 7, 8, 9, 15, 16, 17, 24, 31, 32, 33, 63, 64]
    {
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        fill_positive(&mut rng, &mut a);
        fill_positive(&mut rng, &mut b);
        simd::force_backend(Some(KernelBackend::Scalar));
        let (ds, qs, ns) =
            (simd::dot(&a, &b), simd::sqdist(&a, &b), simd::sqnorm(&a));
        simd::force_backend(Some(KernelBackend::Simd));
        let (dv, qv, nv) =
            (simd::dot(&a, &b), simd::sqdist(&a, &b), simd::sqnorm(&a));
        assert!(
            ulp_diff(ds, dv) <= 4,
            "dot n={n}: {ds} vs {dv} = {} ULP",
            ulp_diff(ds, dv)
        );
        assert!(
            ulp_diff(qs, qv) <= 4,
            "sqdist n={n}: {qs} vs {qv} = {} ULP",
            ulp_diff(qs, qv)
        );
        assert!(
            ulp_diff(ns, nv) <= 4,
            "sqnorm n={n}: {ns} vs {nv} = {} ULP",
            ulp_diff(ns, nv)
        );
    }
}

#[test]
fn scalar_primitives_stay_bit_identical_to_pr5_inline_loops() {
    // the PR 5 goldens are pinned to these exact float orders: the
    // 4-accumulator linalg::dot, the sequential f32 sqdist/sqnorm
    // loops, and the per-element-widening f64 loss accumulator
    let _g = lock_dispatch();
    simd::force_backend(Some(KernelBackend::Scalar));
    let mut rng = Pcg32::new(45);
    for &n in &[1usize, 5, 17, 100, 257, 780] {
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        rng.fill_gaussian(&mut a, 0.0, 1.0);
        rng.fill_gaussian(&mut b, 0.0, 1.0);
        assert_eq!(
            simd::dot(&a, &b).to_bits(),
            linalg::dot(&a, &b).to_bits(),
            "scalar dot must be linalg::dot (n={n})"
        );
        let want_sqd: f32 =
            a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert_eq!(simd::sqdist(&a, &b).to_bits(), want_sqd.to_bits());
        let want_sqn: f32 = a.iter().map(|v| v * v).sum();
        assert_eq!(simd::sqnorm(&a).to_bits(), want_sqn.to_bits());
        let want_f64: f64 = a.iter().map(|v| (v * v) as f64).sum();
        assert_eq!(
            simd::sqnorm_f64(&a).to_bits(),
            want_f64.to_bits()
        );
    }
}

/// The pre-PR6 `eval::nearest_k`, verbatim: insertion + full re-sort.
fn nearest_k_reference(
    gallery: &Mat,
    q: &[f32],
    k: usize,
) -> Vec<(f32, usize)> {
    let mut best: Vec<(f32, usize)> = Vec::with_capacity(k + 1);
    for j in 0..gallery.rows {
        let dist: f32 = q
            .iter()
            .zip(gallery.row(j))
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        if best.len() < k {
            best.push((dist, j));
            best.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        } else if k > 0 && dist < best[k - 1].0 {
            best[k - 1] = (dist, j);
            best.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        }
    }
    best
}

#[test]
fn nearest_k_heap_matches_full_sort_reference_including_ties() {
    // scalar-forced so the blocked scan's distances are bit-identical
    // to the reference's inline loop — any mismatch is selection logic
    let _g = lock_dispatch();
    simd::force_backend(Some(KernelBackend::Scalar));
    let mut rng = Pcg32::new(46);
    let (rows, d) = (200, 5);
    // coordinates on a tiny integer grid → many exactly-tied distances
    let mut gallery = Mat::zeros(rows, d);
    for v in gallery.data.iter_mut() {
        *v = rng.below(3) as f32;
    }
    let q: Vec<f32> = (0..d).map(|_| rng.below(3) as f32).collect();
    for &k in &[0usize, 1, 3, 10, 64, 65, rows, rows + 7] {
        let got = dmlps::eval::nearest_k(&gallery, &q, k);
        let want = nearest_k_reference(&gallery, &q, k);
        assert_eq!(
            got, want,
            "bounded-heap nearest_k diverged from the historical \
             full-sort output (k={k})"
        );
    }
    // and on untied gaussian data across block-boundary gallery sizes
    for &rows in &[1usize, 63, 64, 65, 129] {
        let mut gal = Mat::zeros(rows, d);
        rng.fill_gaussian(&mut gal.data, 0.0, 1.0);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q, 0.0, 1.0);
        for &k in &[1usize, 5, rows] {
            assert_eq!(
                dmlps::eval::nearest_k(&gal, &q, k),
                nearest_k_reference(&gal, &q, k),
                "(rows={rows}, k={k})"
            );
        }
    }
}

#[test]
fn nearest_k_under_simd_is_internally_consistent() {
    // under the vector backend the distances may differ from scalar at
    // rounding level, but the selection must still return exactly the
    // k lexicographically-smallest (dist, idx) pairs of ITS OWN
    // distance set — computed here independently per row
    let _g = lock_dispatch();
    simd::force_backend(Some(KernelBackend::Simd));
    let mut rng = Pcg32::new(47);
    let (rows, d, k) = (150, 33, 9);
    let mut gallery = Mat::zeros(rows, d);
    rng.fill_gaussian(&mut gallery.data, 0.0, 1.0);
    let mut q = vec![0.0f32; d];
    rng.fill_gaussian(&mut q, 0.0, 1.0);
    let got = dmlps::eval::nearest_k(&gallery, &q, k);
    let mut all: Vec<(f32, usize)> = (0..rows)
        .map(|j| (simd::sqdist(&q, gallery.row(j)), j))
        .collect();
    all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    all.truncate(k);
    assert_eq!(got, all);
}

/// The "k > gallery" clamp now lives inside `nearest_k` itself (PR 9):
/// callers pass any k and get `min(k, n)` hits. Pin the edge cases —
/// k=0, k=n, k≫n, empty gallery — under BOTH forced backends, plus the
/// same contract for the subset kernel `nearest_k_among`.
#[test]
fn nearest_k_clamp_edges_hold_under_both_backends() {
    let _g = lock_dispatch();
    let mut rng = Pcg32::new(49);
    let (rows, d) = (67, 9);
    let mut gallery = Mat::zeros(rows, d);
    rng.fill_gaussian(&mut gallery.data, 0.0, 1.0);
    let mut q = vec![0.0f32; d];
    rng.fill_gaussian(&mut q, 0.0, 1.0);
    let empty = Mat::zeros(0, d);
    let all_rows: Vec<usize> = (0..rows).collect();

    for backend in [KernelBackend::Scalar, KernelBackend::Simd] {
        simd::force_backend(Some(backend));

        // k = 0 and empty gallery: empty, no panic, no allocation bomb
        assert!(dmlps::eval::nearest_k(&gallery, &q, 0).is_empty());
        assert!(dmlps::eval::nearest_k(&empty, &q, 5).is_empty());
        assert!(
            dmlps::eval::nearest_k_among(&gallery, &q, 5, &[]).is_empty()
        );

        // k ≥ n clamps: usize::MAX and n+1 both mean "everything",
        // identical to k = n down to the bits
        let full = dmlps::eval::nearest_k(&gallery, &q, rows);
        assert_eq!(full.len(), rows);
        for over in [rows + 1, usize::MAX] {
            let got = dmlps::eval::nearest_k(&gallery, &q, over);
            assert_eq!(got.len(), rows, "clamp to gallery ({backend:?})");
            for ((d1, i1), (d2, i2)) in got.iter().zip(&full) {
                assert_eq!(i1, i2);
                assert_eq!(d1.to_bits(), d2.to_bits());
            }
        }

        // the subset kernel clamps to the candidate count, and over the
        // full (ascending) range it is bit-identical to nearest_k
        let among = dmlps::eval::nearest_k_among(
            &gallery,
            &q,
            usize::MAX,
            &all_rows,
        );
        assert_eq!(among.len(), rows);
        for ((d1, i1), (d2, i2)) in among.iter().zip(&full) {
            assert_eq!(i1, i2);
            assert_eq!(d1.to_bits(), d2.to_bits(), "{backend:?}");
        }
    }
}

#[test]
fn loss_grad_and_pair_dist_backend_agreement() {
    let _g = lock_dispatch();
    let mut rng = Pcg32::new(48);
    let (k, d, bs, bd) = (33, 77, 9, 11);
    let mut l = Mat::zeros(k, d);
    rng.fill_gaussian(&mut l.data, 0.0, 0.3 / (d as f32).sqrt());
    let mut ds = vec![0.0f32; bs * d];
    let mut dd = vec![0.0f32; bd * d];
    rng.fill_gaussian(&mut ds, 0.0, 1.0);
    rng.fill_gaussian(&mut dd, 0.0, 1.0);
    let mut run = |backend| {
        simd::force_backend(Some(backend));
        let mut eng = NativeEngine::with_threads(2);
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
        let mut g = Mat::zeros(k, d);
        let loss = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
        let mut diffs = Mat::zeros(bd, d);
        diffs.data.copy_from_slice(&dd);
        let pd = eng.pair_dist(&l, &diffs).unwrap();
        (loss, g, pd)
    };
    let (ls, gs, ps) = run(KernelBackend::Scalar);
    let (lv, gv, pv) = run(KernelBackend::Simd);
    assert!(
        (ls - lv).abs() <= 1e-5 * (1.0 + ls.abs()),
        "loss: scalar {ls} vs simd {lv}"
    );
    assert!(
        gs.max_abs_diff(&gv) <= 1e-4,
        "grad backend divergence {}",
        gs.max_abs_diff(&gv)
    );
    for (i, (a, b)) in ps.iter().zip(&pv).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
            "pair_dist[{i}]: {a} vs {b}"
        );
    }
}

#[test]
fn forced_simd_degrades_to_scalar_when_unavailable() {
    let _g = lock_dispatch();
    simd::force_backend(Some(KernelBackend::Simd));
    let r = simd::report();
    if !simd::simd_compiled() {
        assert_eq!(r.backend, KernelBackend::Scalar);
        assert_eq!(r.decision, DispatchDecision::NotCompiled);
        assert_eq!(r.lanes, 1);
    } else if !r.cpu_supported {
        assert_eq!(r.backend, KernelBackend::Scalar);
        assert_eq!(r.decision, DispatchDecision::UnsupportedCpu);
    } else {
        assert_eq!(r.backend, KernelBackend::Simd);
        assert_eq!(r.decision, DispatchDecision::Forced);
        assert_eq!(r.lanes, simd::LANES);
    }
    // forcing scalar always sticks, on every build
    simd::force_backend(Some(KernelBackend::Scalar));
    let r = simd::report();
    assert_eq!(r.backend, KernelBackend::Scalar);
    assert_eq!(r.decision, DispatchDecision::Forced);
}

#[test]
fn run_telemetry_reports_kernel_backend() {
    // Run.kernel must reflect the dispatch in effect during the run
    let _g = lock_dispatch();
    simd::force_backend(Some(KernelBackend::Scalar));
    let cfg = dmlps::config::Preset::Tiny.config();
    let run = dmlps::session::Session::from_config(cfg)
        .engine("native")
        .train_sequential()
        .unwrap();
    assert_eq!(run.kernel.backend, KernelBackend::Scalar);
    assert_eq!(run.kernel.decision, DispatchDecision::Forced);
    assert_eq!(run.kernel.compiled_simd, simd::simd_compiled());
}
