//! The public-API suite: `Session` builder golden tests (the deprecated
//! shims must stay bit-identical to the session executors), the
//! `MetricModel` artifact (versioned save/load, error paths, kNN
//! equivalence with `eval::`), the unified `Run` report shape, and the
//! `EventSink` feed. CI runs this file in release mode under a hard
//! timeout (`api-tests`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use dmlps::config::{Consistency, ExperimentConfig, Preset};
use dmlps::data::ExperimentData;
use dmlps::dml::native_factory;
use dmlps::eval::{knn_accuracy, majority_label};
use dmlps::linalg::Mat;
use dmlps::session::{
    config_digest, BroadcastEvent, DoneEvent, EventSink, MetricModel,
    ProbeEvent, RunKind, Session,
};
use dmlps::util::rng::Pcg32;

fn tiny_cfg(steps: usize, workers: usize) -> ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = steps;
    cfg.cluster.workers = workers;
    cfg
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(name)
}

// ---------------------------------------------------------------------
// Golden: the deprecated shims are pinned bit-identical to the session
// ---------------------------------------------------------------------

#[test]
fn sequential_session_matches_deprecated_shim_bit_for_bit() {
    let cfg = tiny_cfg(60, 1);
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));

    #[allow(deprecated)]
    let old = {
        let mut eng = dmlps::dml::NativeEngine::new();
        dmlps::cli::driver::train_single_thread(&cfg, &data, &mut eng, 20)
            .unwrap()
    };
    let new = Session::from_config(cfg)
        .data(data)
        .probe(20, (500, 500))
        .train_sequential()
        .unwrap();

    let model = new.require_model().unwrap();
    assert_eq!(
        old.l.data, model.l().data,
        "Session::train_sequential must reproduce the pre-refactor \
         train_single_thread L bit for bit"
    );
    // probes are deterministic too (times are wall-clock and excluded)
    assert_eq!(old.curve.points.len(), new.curve.points.len());
    for (a, b) in old.curve.points.iter().zip(&new.curve.points) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.objective, b.objective);
    }
    assert_eq!(old.ap_trace.len(), new.ap_trace.len());
    for (a, b) in old.ap_trace.iter().zip(&new.ap_trace) {
        assert_eq!(a.1, b.1, "AP trace values must match exactly");
    }
}

#[test]
fn distributed_session_matches_deprecated_run_training_bit_for_bit() {
    // 1 worker / 1 shard / BSP / mode=none is the deterministic anchor
    // (integration_ps pins the same setting to hand-rolled sequential
    // SGD); here the deprecated ps::run_training shim and the Session
    // executor must agree bit for bit.
    let mut cfg = tiny_cfg(40, 1);
    cfg.cluster.consistency = Consistency::Bsp;
    cfg.cluster.server_shards = 1;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let dataset = Arc::new(dmlps::data::Dataset {
        x: data.train.x.clone(),
        labels: data.train.labels.clone(),
        n_classes: data.train.n_classes,
    });

    #[allow(deprecated)]
    let old = dmlps::ps::run_training(
        &cfg,
        dataset.clone(),
        &data.pairs,
        native_factory(),
        &dmlps::ps::RunOptions::default(),
    )
    .unwrap();
    let new = Session::from_config(cfg)
        .engine_factory(native_factory())
        .pair_source(dataset, data.pairs.clone())
        .train_distributed()
        .unwrap();

    assert_eq!(
        old.l.data,
        new.require_model().unwrap().l().data,
        "Session::train_distributed must reproduce the pre-refactor \
         run_training L bit for bit"
    );
    assert_eq!(old.applied_updates, new.applied_updates);
    assert_eq!(old.slice_updates, new.slice_updates);
    assert_eq!(old.server_shards, new.server_shards);
    assert_eq!(old.grad_bytes_received, new.grad_bytes_received);
}

// ---------------------------------------------------------------------
// MetricModel: versioned artifact round-trip + error paths
// ---------------------------------------------------------------------

#[test]
fn metric_model_save_load_transform_roundtrip_exact() {
    let cfg = Preset::Tiny.config();
    let mut l = Mat::zeros(cfg.model.k, cfg.dataset.dim);
    Pcg32::new(11).fill_gaussian(&mut l.data, 0.0, 0.5);
    let model = MetricModel::new(l, &cfg);

    let p1 = tmp("dmlps_api_model_1.bin");
    let p2 = tmp("dmlps_api_model_2.bin");
    model.save(&p1).unwrap();
    model.save(&p2).unwrap();
    // golden: the byte stream is a pure function of the model
    let (b1, b2) =
        (std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    assert_eq!(b1, b2, "save must be byte-stable across runs");
    // header = 8 magic + 4 version + 4×8 meta; payload = DMLPSMAT
    assert_eq!(
        b1.len(),
        8 + 4 + 32 + (8 + 16 + 4 * cfg.model.k * cfg.dataset.dim),
    );
    assert_eq!(&b1[..8], b"DMLPSMM1");

    let loaded = MetricModel::load(&p1).unwrap();
    assert_eq!(loaded, model, "load must invert save exactly");
    assert_eq!(loaded.meta().seed, cfg.seed);
    assert_eq!(loaded.meta().config_digest, config_digest(&cfg));

    // transform through the reloaded model is bit-identical
    let mut x = Mat::zeros(7, cfg.dataset.dim);
    Pcg32::new(5).fill_gaussian(&mut x.data, 0.0, 1.0);
    assert_eq!(model.transform(&x).data, loaded.transform(&x).data);
}

#[test]
fn metric_model_rejects_truncated_and_wrong_magic() {
    let cfg = Preset::Tiny.config();
    let mut l = Mat::zeros(4, cfg.dataset.dim);
    Pcg32::new(3).fill_gaussian(&mut l.data, 0.0, 0.5);
    let mut cfg4 = cfg.clone();
    cfg4.model.k = 4;
    let model = MetricModel::new(l, &cfg4);
    let path = tmp("dmlps_api_model_err.bin");
    model.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // truncation anywhere — header, meta, payload — must error cleanly
    for cut in [0, 4, 11, 43, 60, bytes.len() - 1] {
        let p = tmp("dmlps_api_model_cut.bin");
        std::fs::write(&p, &bytes[..cut]).unwrap();
        assert!(
            MetricModel::load(&p).is_err(),
            "truncated at {cut} bytes must not load"
        );
    }

    // wrong magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    let p = tmp("dmlps_api_model_magic.bin");
    std::fs::write(&p, &bad).unwrap();
    let err = MetricModel::load(&p).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // unsupported format version
    let mut bad = bytes;
    bad[8] = 99;
    let p = tmp("dmlps_api_model_ver.bin");
    std::fs::write(&p, &bad).unwrap();
    let err = MetricModel::load(&p).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");
}

#[test]
fn metric_model_knn_matches_eval_retrieval() {
    // the model's knn + majority vote must reproduce eval::knn_accuracy
    // exactly — same scan kernel, same tie-breaking
    let cfg = Preset::Tiny.config();
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let mut l = Mat::zeros(cfg.model.k, cfg.dataset.dim);
    Pcg32::new(21).fill_gaussian(&mut l.data, 0.0, 0.4);
    let model = MetricModel::new(l.clone(), &cfg);

    for k in [1usize, 3, 5] {
        let max_test = 60;
        let n = data.test.n().min(max_test);
        let gallery = model.project_gallery(&data.train);
        let mut correct = 0usize;
        for i in 0..n {
            let votes: Vec<u32> = model
                .knn_projected(&gallery, data.test.feature(i), k)
                .into_iter()
                .map(|(j, _)| data.train.labels[j])
                .collect();
            if majority_label(&votes) == Some(data.test.labels[i]) {
                correct += 1;
            }
        }
        let via_model = correct as f64 / n as f64;
        let via_eval =
            knn_accuracy(Some(&l), &data.train, &data.test, k, max_test);
        assert_eq!(via_model, via_eval, "k={k}");
    }
}

#[test]
fn metric_model_pair_dist_matches_transform() {
    let cfg = Preset::Tiny.config();
    let mut l = Mat::zeros(cfg.model.k, cfg.dataset.dim);
    Pcg32::new(9).fill_gaussian(&mut l.data, 0.0, 0.4);
    let model = MetricModel::new(l, &cfg);
    let d = cfg.dataset.dim;
    let mut rng = Pcg32::new(2);
    let mut a = vec![0.0f32; d];
    let mut b = vec![0.0f32; d];
    rng.fill_gaussian(&mut a, 0.0, 1.0);
    rng.fill_gaussian(&mut b, 0.0, 1.0);
    let dist = model.pair_dist(&a, &b);
    // against the batch path
    let mut diffs = Mat::zeros(1, d);
    for (o, (x, y)) in diffs.data.iter_mut().zip(a.iter().zip(&b)) {
        *o = x - y;
    }
    assert_eq!(model.pair_dists(&diffs), vec![dist]);
    assert!(dist >= 0.0 && dist.is_finite());
}

// ---------------------------------------------------------------------
// Unified Run report + builder ergonomics
// ---------------------------------------------------------------------

#[test]
fn run_report_is_unified_across_executors() {
    let cfg = tiny_cfg(30, 2);
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));

    let dist = Session::from_config(cfg.clone())
        .data(data.clone())
        .train_distributed()
        .unwrap();
    assert_eq!(dist.kind, RunKind::Distributed);
    assert_eq!(dist.applied_updates, 60);
    assert_eq!(dist.worker_stats.len(), 2);
    assert!(dist.model.is_some());
    assert!(dist.curve.points.len() >= 2);

    let seq = Session::from_config(cfg.clone())
        .data(data.clone())
        .probe(10, (200, 200))
        .train_sequential()
        .unwrap();
    assert_eq!(seq.kind, RunKind::Sequential);
    assert!(seq.model.is_some());
    assert!(!seq.ap_trace.is_empty());
    assert!(seq.worker_stats.is_empty());

    let sim = Session::from_config(cfg)
        .data(data)
        .topology(2, 4)
        .sim_knobs(dmlps::session::SimKnobs {
            grad_seconds: 0.01,
            total_updates: 100,
            ..Default::default()
        })
        .simulate()
        .unwrap();
    assert_eq!(sim.kind, RunKind::Simulated);
    assert!(sim.model.is_none());
    assert!(sim.require_model().is_err());
    assert!(sim.sim_seconds > 0.0);
    assert!(sim.applied_updates >= 100, "{}", sim.applied_updates);
}

#[test]
fn session_generates_data_when_none_supplied() {
    let run = Session::from_config(tiny_cfg(20, 2))
        .train_distributed()
        .unwrap();
    assert_eq!(run.applied_updates, 40);
}

#[test]
fn simulate_rejects_streaming_and_compressed_configs() {
    let mut cfg = tiny_cfg(10, 1);
    cfg.cluster.pairs.mode = dmlps::config::PairMode::Streaming;
    let err = Session::from_config(cfg).simulate().unwrap_err();
    assert!(err.to_string().contains("materialized"), "{err}");

    let mut cfg = tiny_cfg(10, 1);
    cfg.cluster.compression.mode = dmlps::config::CompressionMode::Int8;
    let err = Session::from_config(cfg).simulate().unwrap_err();
    assert!(err.to_string().contains("dense"), "{err}");
}

#[test]
fn config_digest_tracks_the_config() {
    let a = Preset::Tiny.config();
    let mut b = a.clone();
    assert_eq!(config_digest(&a), config_digest(&b));
    b.seed = 77;
    assert_ne!(config_digest(&a), config_digest(&b));
}

// ---------------------------------------------------------------------
// EventSink: the sanctioned window into a running session
// ---------------------------------------------------------------------

#[derive(Default)]
struct CountingSink {
    probes: AtomicU64,
    broadcasts: AtomicU64,
    dones: AtomicU64,
}

impl EventSink for CountingSink {
    fn on_probe(&self, e: &ProbeEvent) {
        assert!(e.objective.is_finite());
        self.probes.fetch_add(1, Ordering::SeqCst);
    }

    fn on_broadcast(&self, e: &BroadcastEvent) {
        assert!(e.encoded_bytes > 0);
        self.broadcasts.fetch_add(1, Ordering::SeqCst);
    }

    fn on_done(&self, e: &DoneEvent) {
        assert!(e.steps > 0);
        self.dones.fetch_add(1, Ordering::SeqCst);
    }
}

#[test]
fn event_sink_fed_by_distributed_run() {
    let sink = Arc::new(CountingSink::default());
    let run = Session::from_config(tiny_cfg(40, 2))
        .events(sink.clone())
        .train_distributed()
        .unwrap();
    // every curve point was mirrored to the sink
    assert_eq!(
        sink.probes.load(Ordering::SeqCst),
        run.curve.points.len() as u64
    );
    // every broadcast round a shard emitted was reported
    assert_eq!(sink.broadcasts.load(Ordering::SeqCst), run.broadcasts);
    // each worker reported completion
    assert_eq!(sink.dones.load(Ordering::SeqCst), 2);
}

#[test]
fn event_sink_fed_by_sequential_and_simulated_runs() {
    let sink = Arc::new(CountingSink::default());
    let run = Session::from_config(tiny_cfg(30, 1))
        .events(sink.clone())
        .probe(10, (200, 200))
        .train_sequential()
        .unwrap();
    assert_eq!(
        sink.probes.load(Ordering::SeqCst),
        run.curve.points.len() as u64
    );
    assert_eq!(sink.dones.load(Ordering::SeqCst), 0);

    let sink = Arc::new(CountingSink::default());
    let run = Session::from_config(tiny_cfg(10, 1))
        .events(sink.clone())
        .topology(1, 2)
        .sim_knobs(dmlps::session::SimKnobs {
            grad_seconds: 0.01,
            total_updates: 50,
            ..Default::default()
        })
        .simulate()
        .unwrap();
    assert_eq!(
        sink.probes.load(Ordering::SeqCst),
        run.curve.points.len() as u64
    );
}
