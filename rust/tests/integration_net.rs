//! Integration: the socket transport end to end — framing goldens, the
//! TCP backend against the in-memory golden at the same seed, connect
//! retry against a late listener, misroute accounting, and the `dmlps
//! cluster` manager binary driving a real multi-process run.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use dmlps::config::{
    CompressionConfig, Consistency, ExperimentConfig, Preset,
};
use dmlps::data::ExperimentData;
use dmlps::dml::LrSchedule;
use dmlps::linalg::Mat;
use dmlps::metrics::Curve;
use dmlps::ps::frame::{
    decode_frame, encode_encoding, encode_to_server, encode_to_worker,
    encoding_overhead, Frame,
};
use dmlps::ps::net::{
    connect_retry, NetAddr, NetServer, NetWorkerTransport, RetryPolicy,
};
use dmlps::ps::{
    FaultSpec, RunOptions, Server, ServerConfig, ShardPlan, SliceEncoding,
    ToServer, ToWorker, TrainResult, Transport, WorkerStats,
};
use dmlps::session::{
    plan_for, run_server_node, run_worker_node, MetricModel,
};
use dmlps::util::json::Json;

/// Tiny sharded BSP config — small enough to finish in seconds, sharded
/// enough (2 shards) to exercise slice routing on the wire.
fn net_cfg(steps: usize, workers: usize) -> ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = steps;
    cfg.cluster.workers = workers;
    cfg.cluster.server_shards = 2;
    cfg.cluster.consistency = Consistency::Bsp;
    cfg
}

/// Run one full training round over real TCP sockets, every role an
/// in-process thread: bind, accept, connect with retry, train, and join
/// all roles. Each role regenerates the dataset from the config + seed,
/// exactly like `dmlps node` processes do.
fn run_tcp(cfg: &ExperimentConfig) -> (TrainResult, Vec<WorkerStats>) {
    let plan = plan_for(cfg);
    let server =
        NetServer::bind(&NetAddr::parse("127.0.0.1:0").unwrap()).unwrap();
    let addr = server.local_addr().unwrap();
    let workers = cfg.cluster.workers;

    let scfg = cfg.clone();
    let splan = plan.clone();
    let server_h = thread::spawn(move || {
        let data = ExperimentData::generate_for(
            &scfg.dataset, scfg.cluster.pairs.mode, scfg.seed,
        );
        let ExperimentData { train, pairs, .. } = data;
        let mut t =
            server.accept_workers(&splan, scfg.cluster.workers).unwrap();
        let r = run_server_node(
            &scfg, Arc::new(train), &pairs, &RunOptions::default(), None,
            &mut t,
        )
        .unwrap();
        t.finish();
        r
    });

    let mut worker_hs = Vec::new();
    for w in 0..workers {
        let wcfg = cfg.clone();
        let wplan = plan.clone();
        let waddr = addr.clone();
        worker_hs.push(thread::spawn(move || {
            let data = ExperimentData::generate_for(
                &wcfg.dataset, wcfg.cluster.pairs.mode, wcfg.seed,
            );
            let ExperimentData { train, pairs, .. } = data;
            let engines =
                dmlps::dml::engine_factory("native", &wcfg).unwrap();
            let mut t = NetWorkerTransport::connect(
                &waddr, w, &wplan, RetryPolicy::default(),
            )
            .unwrap();
            let ws = run_worker_node(
                &wcfg, w, Arc::new(train), &pairs, engines,
                &RunOptions::default(), None, &mut t,
            )
            .unwrap();
            t.finish();
            ws
        }));
    }

    let r = server_h.join().unwrap();
    let stats: Vec<WorkerStats> =
        worker_hs.into_iter().map(|h| h.join().unwrap()).collect();
    (r, stats)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

// ---------------------------------------------------------------------
// framing goldens
// ---------------------------------------------------------------------

/// The frame layer's byte accounting is the contract telemetry relies
/// on: for every encoding variant, the serialized payload must be
/// exactly `encoding_overhead + encoded_bytes`.
#[test]
fn frame_payload_length_matches_byte_accounting() {
    let encs = [
        SliceEncoding::Dense(vec![1.0, -2.5, 3.25]),
        SliceEncoding::Int8 { scale: 0.5, q: vec![1i8, -3, 7, 0, 2] },
        SliceEncoding::TopK {
            gaps: vec![0, 3, 4],
            vals: vec![1.5, -2.0, 0.25],
        },
        SliceEncoding::TopKInt8 {
            scale: 0.25,
            gaps: vec![2, 1],
            vals: vec![5i8, -9],
        },
    ];
    for enc in &encs {
        let mut buf = Vec::new();
        encode_encoding(enc, &mut buf);
        assert_eq!(
            buf.len() as u64,
            encoding_overhead(enc) + enc.encoded_bytes(),
            "{enc:?}"
        );
    }
}

/// Encode → decode → re-encode must reproduce the wire bytes exactly,
/// in both directions (gradient push and parameter broadcast).
#[test]
fn frames_roundtrip_bitwise() {
    let grad = ToServer::Grad {
        worker: 1,
        shard: 0,
        step: 7,
        grad: SliceEncoding::Dense(vec![
            0.5,
            f32::MIN_POSITIVE,
            -0.0,
            3.75,
        ]),
        loss: 0.125,
    };
    let mut wire = Vec::new();
    encode_to_server(&grad, &mut wire);
    // decode_frame takes the body after the u32 length prefix
    let Frame::ToServer(decoded) = decode_frame(&wire[4..]).unwrap()
    else {
        panic!("grad decoded to the wrong frame kind")
    };
    let mut wire2 = Vec::new();
    encode_to_server(&decoded, &mut wire2);
    assert_eq!(wire, wire2, "grad frame not byte-stable");

    let param = ToWorker::Param {
        shard: 1,
        version: 42,
        clock: 41,
        data: SliceEncoding::Int8 { scale: 0.03125, q: vec![0i8, -128, 127] },
    };
    let mut wire = Vec::new();
    encode_to_worker(&param, &mut wire);
    let Frame::ToWorker(decoded) = decode_frame(&wire[4..]).unwrap()
    else {
        panic!("param decoded to the wrong frame kind")
    };
    let mut wire2 = Vec::new();
    encode_to_worker(&decoded, &mut wire2);
    assert_eq!(wire, wire2, "param frame not byte-stable");
}

// ---------------------------------------------------------------------
// TCP backend vs the in-memory golden
// ---------------------------------------------------------------------

/// With one worker under BSP the fold order is fully deterministic, so
/// the socket transport must produce the *bit-identical* final L the
/// in-memory channels produce at the same seed — dense f32 payloads
/// roundtrip through the wire via to_bits/from_bits exactly.
#[test]
fn tcp_one_worker_bsp_is_bit_identical_to_memory() {
    let cfg = net_cfg(40, 1);
    let (r, stats) = run_tcp(&cfg);
    assert_eq!(stats[0].steps_done, 40);
    assert_eq!(stats[0].grads_sent + stats[0].grads_dropped, 40);
    assert_eq!(r.misroutes, 0);

    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let m = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default(),
    )
    .unwrap();
    assert_eq!(
        r.l.data, m.l.data,
        "socket transport diverged from in-memory at 1 worker BSP"
    );
}

/// With two workers the per-round fold *order* is scheduling-dependent
/// (f32 addition is not associative), so cross-transport agreement is
/// within a small tolerance rather than bitwise. The accounting
/// identity `sent + dropped == steps` must hold exactly per worker.
#[test]
fn tcp_two_workers_bsp_matches_memory_within_tolerance() {
    let cfg = net_cfg(40, 2);
    let (r, stats) = run_tcp(&cfg);
    assert_eq!(stats.len(), 2);
    for ws in &stats {
        assert_eq!(ws.steps_done, 40, "worker {}", ws.id);
        assert_eq!(
            ws.grads_sent + ws.grads_dropped, 40,
            "worker {} accounting identity broken", ws.id
        );
        assert_eq!(ws.grads_dropped, 0, "perfect link dropped grads");
    }
    assert_eq!(r.misroutes, 0);
    assert_eq!(r.applied_updates, 80);

    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let m = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default(),
    )
    .unwrap();
    let diff = max_abs_diff(&r.l.data, &m.l.data);
    assert!(
        diff < 1e-2,
        "TCP vs in-memory max abs diff {diff} exceeds f32 \
         fold-order tolerance"
    );
}

// ---------------------------------------------------------------------
// connect retry
// ---------------------------------------------------------------------

/// Workers may come up before the server: connect_retry must keep
/// trying (with backoff) until the listener appears.
#[test]
fn connect_retry_waits_for_late_listener() {
    // reserve a kernel-chosen port, free it, and bind it late
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);

    let listen_addr = addr.clone();
    let listener = thread::spawn(move || {
        thread::sleep(Duration::from_millis(150));
        let l = std::net::TcpListener::bind(&listen_addr).unwrap();
        let _ = l.accept();
    });

    let policy = RetryPolicy {
        attempts: 100,
        initial_backoff: Duration::from_millis(10),
        max_backoff: Duration::from_millis(50),
    };
    let stream = connect_retry(&NetAddr::parse(&addr).unwrap(), policy);
    assert!(
        stream.is_ok(),
        "late listener should be reachable: {:?}",
        stream.err()
    );
    drop(stream);
    listener.join().unwrap();
}

/// With nothing ever listening the retry budget is bounded: a small
/// attempt count must fail fast instead of hanging the node.
#[test]
fn connect_retry_gives_up_after_bounded_attempts() {
    let start = Instant::now();
    let policy = RetryPolicy {
        attempts: 3,
        initial_backoff: Duration::from_millis(5),
        max_backoff: Duration::from_millis(10),
    };
    // port 1 is privileged and unbound: connects are refused immediately
    let r = connect_retry(&NetAddr::parse("127.0.0.1:1").unwrap(), policy);
    assert!(r.is_err(), "connect to an unbound port must fail");
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "bounded retry took {:?}",
        start.elapsed()
    );
}

// ---------------------------------------------------------------------
// misroute accounting
// ---------------------------------------------------------------------

/// A gradient naming a shard outside the plan must be counted and
/// skipped by the server router — never folded, never a panic, and the
/// valid messages around it still apply.
#[test]
fn server_counts_and_skips_misrouted_gradients() {
    let plan = ShardPlan::new(8, 4, 1);
    let slice_len = plan.len(0);
    let l0 = Mat::zeros(8, 4);
    let (tx, rx) = channel::<ToServer>();
    let (wtx, _wrx) = channel::<ToWorker>();
    let cfg = ServerConfig {
        workers: 1,
        server_batch: 8,
        lr: LrSchedule::new(0.1, 0.0),
        lr_scale: 1.0,
        probe_every: 1_000,
        faults: FaultSpec::perfect(),
        seed: 1,
        compression: CompressionConfig::default(),
        events: None,
        checkpoint: None,
        resume: None,
    };
    let server = Server::spawn(
        cfg,
        plan,
        l0,
        rx,
        vec![wtx],
        Box::new(|_l: &Mat, _u: u64, _t: f64, _c: &mut Curve| {}),
    );
    tx.send(ToServer::Grad {
        worker: 0,
        shard: 0,
        step: 0,
        grad: SliceEncoding::Dense(vec![0.25; slice_len]),
        loss: 0.5,
    })
    .unwrap();
    tx.send(ToServer::Grad {
        worker: 0,
        shard: 5, // outside the 1-shard plan
        step: 1,
        grad: SliceEncoding::Dense(vec![0.25; slice_len]),
        loss: 0.5,
    })
    .unwrap();
    tx.send(ToServer::Done { worker: 0 }).unwrap();
    drop(tx);
    let r = server.join();
    assert_eq!(r.misroutes, 1, "misrouted grad not counted");
    assert_eq!(r.applied_updates, 1, "valid grad around it must apply");
}

// ---------------------------------------------------------------------
// manager binary end to end
// ---------------------------------------------------------------------

/// `dmlps cluster` spawns a real server process and two worker
/// processes over TCP, enforces the accounting identity, and saves a
/// model whose L matches an in-memory run at the same seed.
#[test]
fn manager_cluster_run_matches_memory() {
    let dir = std::env::temp_dir()
        .join(format!("dmlps-net-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.bin");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_dmlps"))
        .args([
            "cluster",
            "--preset", "tiny",
            "--workers", "2",
            "--server-shards", "2",
            "--steps", "30",
            "--consistency", "bsp",
            "--engine", "native",
            "--timeout-s", "120",
        ])
        .arg("--run-dir")
        .arg(&dir)
        .arg("--save-model")
        .arg(&model_path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "cluster run failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );

    let model = MetricModel::load(&model_path).unwrap();

    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 30;
    cfg.cluster.workers = 2;
    cfg.cluster.server_shards = 2;
    cfg.cluster.consistency = Consistency::Bsp;
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let m = dmlps::cli::driver::train_distributed(
        &cfg, &data, "native", &RunOptions::default(),
    )
    .unwrap();
    assert_eq!(model.l().rows, m.l.rows);
    assert_eq!(model.l().cols, m.l.cols);
    let diff = max_abs_diff(&model.l().data, &m.l.data);
    assert!(
        diff < 1e-2,
        "cluster vs in-memory max abs diff {diff} exceeds f32 \
         fold-order tolerance"
    );

    // combined report: no misroutes, no rejected frames on a clean run
    let combined = Json::parse_file(&dir.join("cluster.json")).unwrap();
    assert_eq!(
        combined.get("server").get("misroutes").as_f64(),
        Some(0.0),
        "healthy run must not misroute"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// elasticity: SIGKILL a role mid-run, restart from checkpoint
// ---------------------------------------------------------------------

/// Drive one `dmlps cluster` run (2 workers, 2 shards, BSP, 400 steps)
/// in `dir` with extra manager flags, assert it succeeds, and return the
/// combined report. The manager itself enforces the per-worker
/// `start_step + grads_sent + grads_dropped == steps` identity, so a
/// successful exit already proves the accounting survived any restarts.
fn run_manager(dir: &std::path::Path, extra: &[&str]) -> Json {
    std::fs::create_dir_all(dir).unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_dmlps"))
        .args([
            "cluster",
            "--preset", "tiny",
            "--workers", "2",
            "--server-shards", "2",
            "--steps", "400",
            "--consistency", "bsp",
            "--engine", "native",
            "--timeout-s", "240",
        ])
        .arg("--run-dir")
        .arg(dir)
        .args(extra)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "cluster run failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    Json::parse_file(&dir.join("cluster.json")).unwrap()
}

/// SIGKILL a role once the first checkpoint generation is durable, let
/// `--restart-policy cluster` respawn everything with `--resume`, and
/// require (a) a restart actually happened, (b) every respawned worker
/// re-entered past step 0, and (c) the final objective lands within a
/// loose tolerance of an undisturbed run — re-folded replayed gradients
/// perturb the trajectory but must not derail convergence.
fn assert_survives_sigkill(tag: &str, chaos: &str) {
    let base = std::env::temp_dir()
        .join(format!("dmlps-elastic-{tag}-{}", std::process::id()));
    let undisturbed = run_manager(&base.join("baseline"), &[]);
    let disturbed = run_manager(&base.join("chaos"), &[
        "--ckpt-every-steps", "5",
        "--restart-policy", "cluster",
        "--chaos-kill", chaos,
    ]);

    assert_eq!(
        undisturbed.get("attempts").as_f64(),
        Some(1.0),
        "baseline must not restart"
    );
    let attempts = disturbed.get("attempts").as_f64().unwrap();
    assert!(
        attempts >= 2.0,
        "chaos kill '{chaos}' never triggered a restart \
         (attempts = {attempts}) — the run finished before the first \
         checkpoint generation landed"
    );
    if let Json::Arr(workers) = disturbed.get("workers") {
        assert_eq!(workers.len(), 2);
        for w in workers {
            let start = w.get("start_step").as_f64().unwrap();
            assert!(
                start > 0.0,
                "worker {:?} restarted from step 0 — checkpoint state \
                 was not restored",
                w.get("worker"),
            );
        }
    } else {
        panic!("combined report has no workers array");
    }

    let base_obj =
        undisturbed.get("server").get("final_objective").as_f64().unwrap();
    let dist_obj =
        disturbed.get("server").get("final_objective").as_f64().unwrap();
    let rel = (dist_obj - base_obj).abs() / base_obj.abs().max(1e-6);
    assert!(
        rel < 0.25,
        "disturbed objective {dist_obj} vs undisturbed {base_obj}: \
         relative gap {rel:.3} exceeds the recovery tolerance"
    );
    let _ = std::fs::remove_dir_all(&base);
}

/// Kill one worker process mid-run. The whole cluster respawns and
/// resumes from the newest consistent generation.
#[test]
fn cluster_recovers_from_worker_sigkill() {
    assert_survives_sigkill("worker", "worker1@ckpt");
}

/// Kill the server process (all shards) mid-run. Its state survives
/// only through the checkpoint directory.
#[test]
fn cluster_recovers_from_server_sigkill() {
    assert_survives_sigkill("server", "server@ckpt");
}
