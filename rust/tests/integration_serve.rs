//! End-to-end serving tests: golden wire bytes, and a real `dmlps
//! serve` subprocess queried over TCP.
//!
//! The golden arrays pin the serving protocol the same way
//! `integration_net` pins the PS protocol: the exact bytes of a
//! query/answer pair are hardcoded, so any codec change that shifts
//! the wire layout fails here before it silently strands old clients.

use std::io::{Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::linalg::Mat;
use dmlps::ps::net::{NetAddr, RetryPolicy};
use dmlps::serve::frame::{
    decode_frame, encode_frame, SERVE_PROTOCOL_VERSION,
};
use dmlps::serve::{ServeClient, ServeFrame};
use dmlps::session::Session;

// ---------------------------------------------------------------------
// golden wire bytes
// ---------------------------------------------------------------------

/// Query{id:7, k:3, nprobe:2, x: 1×2 [1.5, -2.0]} — every byte pinned.
const GOLDEN_QUERY: [u8; 37] = [
    0x21, 0x00, 0x00, 0x00, // body_len = 33
    0x31, // KIND_QUERY
    0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id = 7
    0x03, 0x00, 0x00, 0x00, // k = 3
    0x02, 0x00, 0x00, 0x00, // nprobe = 2
    0x01, 0x00, 0x00, 0x00, // nrows = 1
    0x02, 0x00, 0x00, 0x00, // dim = 2
    0x00, 0x00, 0xC0, 0x3F, // 1.5f32
    0x00, 0x00, 0x00, 0xC0, // -2.0f32
];

/// Answer{id:7, version:42, results:[[(5, 0.25), (9, 1.5)]]}.
const GOLDEN_ANSWER: [u8; 45] = [
    0x29, 0x00, 0x00, 0x00, // body_len = 41
    0x41, // KIND_ANSWER
    0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id = 7
    0x2A, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // version = 42
    0x01, 0x00, 0x00, 0x00, // nrows = 1
    0x02, 0x00, 0x00, 0x00, // row 0: 2 hits
    0x05, 0x00, 0x00, 0x00, // hit 0: idx 5
    0x00, 0x00, 0x80, 0x3E, // hit 0: dist 0.25f32
    0x09, 0x00, 0x00, 0x00, // hit 1: idx 9
    0x00, 0x00, 0xC0, 0x3F, // hit 1: dist 1.5f32
];

fn golden_query_frame() -> ServeFrame {
    ServeFrame::Query {
        id: 7,
        k: 3,
        nprobe: 2,
        x: Mat::from_vec(1, 2, vec![1.5, -2.0]),
    }
}

fn golden_answer_frame() -> ServeFrame {
    ServeFrame::Answer {
        id: 7,
        version: 42,
        results: vec![vec![(5, 0.25), (9, 1.5)]],
    }
}

#[test]
fn serving_wire_format_is_golden_pinned() {
    for (frame, golden) in [
        (golden_query_frame(), &GOLDEN_QUERY[..]),
        (golden_answer_frame(), &GOLDEN_ANSWER[..]),
    ] {
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        assert_eq!(
            wire, golden,
            "encoder drifted from the pinned wire bytes for {frame:?}"
        );
        let decoded = decode_frame(&golden[4..]).unwrap();
        assert_eq!(decoded, frame, "decoder drifted on the pinned bytes");
    }
    // the handshake greeting too: 3-byte body, version 1
    let mut hello = Vec::new();
    encode_frame(
        &ServeFrame::Hello { protocol: SERVE_PROTOCOL_VERSION },
        &mut hello,
    );
    assert_eq!(hello, [0x03, 0x00, 0x00, 0x00, 0x51, 0x01, 0x00]);
}

// ---------------------------------------------------------------------
// e2e: train → save → `dmlps serve` subprocess → query over TCP
// ---------------------------------------------------------------------

struct KillOnDrop(std::process::Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn wait_addr_file(path: &std::path::Path) -> String {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            if !s.trim().is_empty() {
                return s.trim().to_string();
            }
        }
        assert!(
            Instant::now() < deadline,
            "server never published {} — did `dmlps serve` start?",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Read one frame off a raw socket (length prefix + body).
fn raw_recv(s: &mut std::net::TcpStream) -> ServeFrame {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).expect("read length prefix");
    let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
    s.read_exact(&mut body).expect("read body");
    decode_frame(&body).expect("decode reply")
}

fn raw_send(s: &mut std::net::TcpStream, f: &ServeFrame) {
    let mut buf = Vec::new();
    encode_frame(f, &mut buf);
    s.write_all(&buf).expect("write frame");
}

#[test]
fn serve_subprocess_end_to_end() {
    let dir = std::env::temp_dir()
        .join(format!("dmlps-serve-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.bin");
    let addr_file = dir.join("serve.addr");

    // train tiny in-process and persist the artifact the server loads
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 40;
    cfg.cluster.workers = 1;
    let data = Arc::new(ExperimentData::generate_for(
        &cfg.dataset,
        cfg.cluster.pairs.mode,
        cfg.seed,
    ));
    let run = Session::from_config(cfg)
        .data(Arc::clone(&data))
        .train_sequential()
        .unwrap();
    let model = run.require_model().unwrap();
    model.save(&model_path).unwrap();

    let child = std::process::Command::new(env!("CARGO_BIN_EXE_dmlps"))
        .args(["serve", "--preset", "tiny", "--addr", "127.0.0.1:0"])
        .arg("--model")
        .arg(&model_path)
        .arg("--addr-file")
        .arg(&addr_file)
        .spawn()
        .unwrap();
    let _guard = KillOnDrop(child);
    let addr_str = wait_addr_file(&addr_file);
    let addr = NetAddr::parse(&addr_str).unwrap();

    // --- wire answers are bit-identical to in-process MetricModel::knn
    let (mut client, info) =
        ServeClient::connect(&addr, RetryPolicy::default()).unwrap();
    assert_eq!(info.dim, model.dim());
    assert_eq!(info.gallery as usize, data.train.n());
    let k = 7;
    let b = 5;
    let mut x = Mat::zeros(b, data.test.dim());
    for r in 0..b {
        x.row_mut(r).copy_from_slice(data.test.feature(r * 17));
    }
    let (version, results) = client.query(&x, k, 0, 99).unwrap();
    assert_eq!(version, 1);
    assert_eq!(results.len(), b);
    for (r, row) in results.iter().enumerate() {
        let want = model.knn(&data.train, x.row(r), k);
        assert_eq!(row.len(), want.len(), "row {r} hit count");
        for (&(gi, gd), &(wi, wd)) in row.iter().zip(&want) {
            assert_eq!(gi as usize, wi, "row {r} index");
            assert_eq!(
                gd.to_bits(),
                wd.to_bits(),
                "row {r} distance must be bit-identical over the wire"
            );
        }
    }

    // --- malformed + oversized frames: rejected, counted, survived
    let mut raw = std::net::TcpStream::connect(&addr_str).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    raw_send(
        &mut raw,
        &ServeFrame::Hello { protocol: SERVE_PROTOCOL_VERSION },
    );
    assert!(matches!(raw_recv(&mut raw), ServeFrame::HelloAck { .. }));

    // malformed: unknown kind byte in a sound frame
    raw.write_all(&[3, 0, 0, 0, 0x7E, 0xAA, 0xBB]).unwrap();
    match raw_recv(&mut raw) {
        ServeFrame::Error { id, message } => {
            assert_eq!(id, 0);
            assert!(message.contains("unknown kind"), "got: {message}");
        }
        other => panic!("expected Error for unknown kind, got {other:?}"),
    }

    // oversized: over the server's body limit (but under the hard cap);
    // the body is skipped, never buffered, and the connection lives on
    let oversized = (1usize << 22) + 1;
    raw.write_all(&(oversized as u32).to_le_bytes()).unwrap();
    let junk = vec![0u8; 1 << 16];
    let mut left = oversized;
    while left > 0 {
        let n = left.min(junk.len());
        raw.write_all(&junk[..n]).unwrap();
        left -= n;
    }
    match raw_recv(&mut raw) {
        ServeFrame::Error { message, .. } => {
            assert!(message.contains("exceeds limit"), "got: {message}");
        }
        other => panic!("expected Error for oversized, got {other:?}"),
    }

    // the same connection still answers a good query afterwards
    raw_send(
        &mut raw,
        &ServeFrame::Query {
            id: 5,
            k: 3,
            nprobe: 0,
            x: Mat::from_vec(1, info.dim, vec![0.0; info.dim]),
        },
    );
    match raw_recv(&mut raw) {
        ServeFrame::Answer { id, results, .. } => {
            assert_eq!(id, 5);
            assert_eq!(results.len(), 1);
            assert_eq!(results[0].len(), 3);
        }
        other => panic!("expected Answer after rejections, got {other:?}"),
    }

    // both rejections were counted
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.rejected, 2,
        "exactly the malformed and oversized frames must be counted"
    );
    assert_eq!(stats.swaps, 0);

    let _ = std::fs::remove_dir_all(&dir);
}
