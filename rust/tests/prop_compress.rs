//! Property suite for the PS wire-compression layer — the contracts the
//! protocol relies on, pinned the same way `prop_pairstream.rs` pins
//! the pair sampler:
//!
//! * stochastic int8 rounding is **unbiased** (empirical mean of
//!   decode(encode(x)) converges to x over seeded draws);
//! * round-trip error is **bounded by the per-slice scale**;
//! * top-k keeps **exactly `ceil(keep·len)`** coordinates and retains
//!   the **largest magnitudes**;
//! * encode/decode is a **pure function of (worker, shard, step)** —
//!   the same keying contract the pair sampler pins for `(seed, w, t)`;
//! * error feedback **conserves update mass**: what compression drops
//!   or rounds away is delivered later, never lost.

use dmlps::config::{CompressionConfig, CompressionMode};
use dmlps::ps::{
    decode_into, encode_param, keep_count, Compressor, ShardPlan,
};
use dmlps::util::rng::Pcg32;

/// A deterministic test slice with mixed signs and magnitudes.
fn test_slice(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_gaussian(&mut v, 0.0, 1.0);
    v
}

fn decode(enc: &dmlps::ps::SliceEncoding, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    decode_into(enc, &mut out);
    out
}

#[test]
fn stochastic_int8_rounding_is_unbiased() {
    let n = 64;
    let x = test_slice(n, 7);
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = amax / 127.0;
    let trials = 4_000u64;
    let mut mean = vec![0.0f64; n];
    for t in 0..trials {
        // encode_param is the residual-free path: every draw sees the
        // same input, keyed by a fresh (shard, version) pair
        let enc = encode_param(CompressionMode::Int8, 11, 0, t, &x);
        for (m, d) in mean.iter_mut().zip(decode(&enc, n)) {
            *m += d as f64;
        }
    }
    // per-coordinate: SE = scale/(2·√trials) ≈ 0.008·scale; 0.2·scale
    // is ~25 SE of headroom yet still catches deterministic rounding,
    // whose bias reaches 0.5·scale at frac ≈ 0.5
    let mut bias_sum = 0.0f64;
    for (m, &xi) in mean.iter().zip(&x) {
        let err = m / trials as f64 - xi as f64;
        assert!(
            err.abs() <= 0.2 * scale as f64,
            "biased coordinate: mean err {err}, scale {scale}"
        );
        bias_sum += err;
    }
    // signed bias averaged across coordinates must vanish much faster
    // (floor-rounding would leave ≈ −0.5·scale here)
    assert!(
        (bias_sum / n as f64).abs() <= 0.02 * scale as f64,
        "systematic bias: {}",
        bias_sum / n as f64
    );
}

#[test]
fn int8_roundtrip_error_is_bounded_by_scale() {
    for seed in 0..20u64 {
        let n = 257;
        let x = test_slice(n, seed);
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = amax / 127.0;
        let enc = encode_param(CompressionMode::Int8, seed, 3, 1, &x);
        let dec = decode(&enc, n);
        for (d, &xi) in dec.iter().zip(&x) {
            assert!(
                (d - xi).abs() <= scale * (1.0 + 1e-4),
                "seed {seed}: |{d} - {xi}| > scale {scale}"
            );
        }
    }
}

#[test]
fn topk_keeps_exact_count_of_largest_magnitudes() {
    let plan = ShardPlan::new(27, 37, 1); // one shard of 999 elements
    let n = plan.len(0);
    for keep in [0.01f32, 0.1, 0.25, 0.5, 1.0] {
        let x = test_slice(n, 1 + keep.to_bits() as u64);
        let mut c = Compressor::new(
            CompressionConfig { mode: CompressionMode::TopK, keep },
            5,
            0,
            &plan,
        );
        let enc = c.encode_grad(0, 0, &x);
        let expected = keep_count(keep, n);
        assert_eq!(
            expected,
            (keep as f64 * n as f64).ceil() as usize,
            "keep_count must be ceil(keep·len) here"
        );
        assert_eq!(enc.nnz(), expected, "keep={keep}");
        let dec = decode(&enc, n);
        // kept f32 values ship exactly; everything else decodes to zero
        let kept: Vec<usize> =
            (0..n).filter(|&i| dec[i] != 0.0).collect();
        assert_eq!(kept.len(), expected, "keep={keep} (no zero draws)");
        for &i in &kept {
            assert_eq!(dec[i], x[i], "kept values must be exact");
        }
        let min_kept = kept
            .iter()
            .map(|&i| x[i].abs())
            .fold(f32::INFINITY, f32::min);
        let max_dropped = (0..n)
            .filter(|i| !kept.contains(i))
            .map(|i| x[i].abs())
            .fold(0.0f32, f32::max);
        assert!(
            min_kept >= max_dropped,
            "keep={keep}: kept {min_kept} < dropped {max_dropped}"
        );
    }
}

#[test]
fn topk_gap_stream_survives_large_gaps() {
    // sparse keeps over a long slice force multi-byte varint gaps
    let plan = ShardPlan::new(100, 1000, 1); // 100k elements
    let n = plan.len(0);
    let mut x = vec![0.0f32; n];
    // a handful of spikes far apart (gaps ≫ 127), incl. the endpoints
    for (j, &i) in [0usize, 300, 17_000, 65_000, n - 1].iter().enumerate()
    {
        x[i] = (j as f32 + 1.0) * if j % 2 == 0 { 1.0 } else { -1.0 };
    }
    // 4.5e-5 · 100_000 = 4.5 → ceil 5, robust to f32 representation
    let mut c = Compressor::new(
        CompressionConfig { mode: CompressionMode::TopK, keep: 4.5e-5 },
        9,
        0,
        &plan,
    );
    assert_eq!(keep_count(4.5e-5, n), 5);
    let dec = decode(&c.encode_grad(0, 0, &x), n);
    assert_eq!(dec, x, "spikes must round-trip exactly");
}

#[test]
fn encoding_is_pure_in_worker_shard_step() {
    let plan = ShardPlan::new(16, 33, 4);
    let cfg = CompressionConfig {
        mode: CompressionMode::TopKInt8,
        keep: 0.25,
    };
    let make = |worker: usize| Compressor::new(cfg, 21, worker, &plan);
    let n = plan.len(1);
    let (g0, g1) = (test_slice(n, 100), test_slice(n, 101));

    // same (worker, shard, step) history ⇒ bit-identical wire traffic
    let (mut a, mut b) = (make(3), make(3));
    for (step, g) in [(0u64, &g0), (1u64, &g1)] {
        let (ea, eb) =
            (a.encode_grad(1, step, g), b.encode_grad(1, step, g));
        assert_eq!(decode(&ea, n), decode(&eb, n), "step {step}");
        assert_eq!(ea.encoded_bytes(), eb.encoded_bytes());
        assert_eq!(a.residual(1), b.residual(1), "residuals diverged");
    }

    // a different worker, shard, or step keys a different stream
    let mut w_other = make(4);
    let e_other = w_other.encode_grad(1, 0, &g0);
    let mut base = make(3);
    let e_base = base.encode_grad(1, 0, &g0);
    assert_ne!(
        decode(&e_base, n),
        decode(&e_other, n),
        "worker must key the rounding stream"
    );
    let mut s_other = make(3);
    let e_step = s_other.encode_grad(1, 7, &g0);
    assert_ne!(
        decode(&e_base, n),
        decode(&e_step, n),
        "step must key the rounding stream"
    );
}

#[test]
fn error_feedback_conserves_update_mass() {
    let plan = ShardPlan::new(12, 31, 3);
    for mode in [CompressionMode::Int8, CompressionMode::TopK,
                 CompressionMode::TopKInt8] {
        let mut c = Compressor::new(
            CompressionConfig { mode, keep: 0.1 },
            17,
            2,
            &plan,
        );
        let shard = 1;
        let n = plan.len(shard);
        let steps = 50u64;
        let mut sum_in = vec![0.0f64; n];
        let mut sum_out = vec![0.0f64; n];
        for t in 0..steps {
            let g = test_slice(n, 1000 + t);
            for (s, &gi) in sum_in.iter_mut().zip(&g) {
                *s += gi as f64;
            }
            let dec = decode(&c.encode_grad(shard, t, &g), n);
            for (s, &di) in sum_out.iter_mut().zip(&dec) {
                *s += di as f64;
            }
        }
        // Σ decoded + residual == Σ gradients (up to f32 round-off):
        // compression delays mass, never loses it
        for i in 0..n {
            let delivered = sum_out[i] + c.residual(shard)[i] as f64;
            assert!(
                (delivered - sum_in[i]).abs() <= 1e-3,
                "{mode:?} coord {i}: Σin {} vs delivered {delivered}",
                sum_in[i]
            );
        }
        // and with a 10% keep over 50 steps the residual must actually
        // be in play for the sparsifying modes
        if mode.sparsifies() {
            let live = c
                .residual(shard)
                .iter()
                .filter(|r| r.abs() > 1e-6)
                .count();
            assert!(live > 0, "{mode:?}: error feedback inactive");
        }
    }
}

#[test]
fn dense_and_none_paths_are_bit_exact() {
    let plan = ShardPlan::new(9, 14, 2);
    let mut c = Compressor::new(
        CompressionConfig::default(), // mode = none
        3,
        1,
        &plan,
    );
    for shard in 0..plan.shards() {
        let x = test_slice(plan.len(shard), 40 + shard as u64);
        let enc = c.encode_grad(shard, 0, &x);
        assert_eq!(enc.encoded_bytes(), 4 * x.len() as u64);
        assert_eq!(decode(&enc, x.len()), x, "must be a verbatim copy");
    }
    // parameter broadcasts: none/topk stay dense f32
    let x = test_slice(50, 44);
    for mode in [CompressionMode::None, CompressionMode::TopK] {
        let enc = encode_param(mode, 3, 0, 1, &x);
        assert_eq!(decode(&enc, 50), x, "{mode:?}");
        assert_eq!(enc.encoded_bytes(), 200);
    }
}

#[test]
fn topk_int8_meets_the_four_x_byte_budget() {
    // the acceptance-criterion arithmetic, pinned at the unit level:
    // keep=0.25 with 1-byte average gaps and int8 values must encode
    // at least 4× smaller than dense f32
    let plan = ShardPlan::new(25, 40, 1); // 1000 elements
    let n = plan.len(0);
    let x = test_slice(n, 77);
    let mut c = Compressor::new(
        CompressionConfig {
            mode: CompressionMode::TopKInt8,
            keep: 0.25,
        },
        5,
        0,
        &plan,
    );
    let enc = c.encode_grad(0, 0, &x);
    let dense = 4 * n as u64;
    assert!(
        enc.encoded_bytes() * 4 <= dense,
        "topk_int8@0.25 over-budget: {} vs dense {dense}",
        enc.encoded_bytes()
    );
}
