//! Integration: AOT artifacts → PJRT runtime → engine parity.
//! Requires `make artifacts`; tests self-skip when absent.
//! The whole file needs the PJRT bindings, so it only exists under the
//! `xla` cargo feature (the offline default build has no XLA runtime).
#![cfg(feature = "xla")]

use dmlps::dml::{Engine, MinibatchRef, NativeEngine};
use dmlps::linalg::Mat;
use dmlps::runtime::{artifacts_available, artifacts_dir, Manifest, XlaEngine};
use dmlps::util::rng::Pcg32;

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return true;
    }
    false
}

#[test]
fn manifest_covers_all_config_variants() {
    if skip() { return; }
    let m = Manifest::load(&artifacts_dir()).unwrap();
    for preset in dmlps::config::Preset::all() {
        let cfg = preset.config();
        let variant = cfg.artifact_variant.unwrap();
        let shape = m.variant(&variant).unwrap();
        assert_eq!(shape.k, cfg.model.k, "{variant} k");
        assert_eq!(shape.d, cfg.dataset.dim, "{variant} d");
        assert_eq!(shape.bs, cfg.optim.batch_sim, "{variant} bs");
        for f in ["loss_grad", "step", "pair_dist"] {
            assert!(m.entry(&variant, f).is_ok(), "{variant}.{f}");
        }
    }
}

#[test]
fn xla_and_native_agree_on_training_trajectory() {
    if skip() { return; }
    // 20 SGD steps from the same init on the same batches must produce
    // near-identical L under both engines (end-to-end numeric parity).
    let mut xe = XlaEngine::load(&artifacts_dir(), "test_small").unwrap();
    let s = xe.shape();
    let mut ne = NativeEngine::new();
    let mut rng = Pcg32::new(42);
    let mut lx = Mat::zeros(s.k, s.d);
    rng.fill_gaussian(&mut lx.data, 0.0, 0.2);
    let mut ln = lx.clone();
    for step in 0..20 {
        let mut ds = vec![0.0f32; s.bs * s.d];
        let mut dd = vec![0.0f32; s.bd * s.d];
        rng.fill_gaussian(&mut ds, 0.0, 1.0);
        rng.fill_gaussian(&mut dd, 0.0, 1.0);
        let b1 = MinibatchRef::new(&ds, &dd, s.bs, s.bd, s.d);
        let fx = xe.step(&mut lx, &b1, 1.0, 0.05).unwrap();
        let b2 = MinibatchRef::new(&ds, &dd, s.bs, s.bd, s.d);
        let fn_ = ne.step(&mut ln, &b2, 1.0, 0.05).unwrap();
        assert!((fx - fn_).abs() < 1e-3 * (1.0 + fn_.abs()),
                "step {step}: loss {fx} vs {fn_}");
    }
    assert!(lx.max_abs_diff(&ln) < 1e-2, "trajectory diverged");
}

#[test]
fn xla_engine_through_ps_training() {
    if skip() { return; }
    // full distributed path over the XLA engine on the tiny preset
    let mut cfg = dmlps::config::Preset::Tiny.config();
    cfg.optim.steps = 30;
    cfg.cluster.workers = 2;
    let data = dmlps::data::ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = dmlps::cli::driver::train_distributed(
        &cfg, &data, "xla", &dmlps::ps::RunOptions::default()).unwrap();
    assert_eq!(r.applied_updates, 60);
    let first = r.curve.points.first().unwrap().objective;
    let last = r.curve.points.last().unwrap().objective;
    assert!(last < first, "objective should decrease: {first} -> {last}");
}
