//! Property tests over coordinator invariants: routing (partitioning),
//! batching, state (PS protocol), and numeric invariants of the
//! objective — via the in-tree `util::check` harness.

use dmlps::data::{partition_pairs, PairSet, SyntheticSpec};
use dmlps::dml::{DmlProblem, Engine, MinibatchRef, NativeEngine};
use dmlps::linalg::Mat;
use dmlps::util::check::forall;
use dmlps::util::rng::Pcg32;

#[test]
fn prop_partition_is_exact_cover() {
    forall("partition covers every pair exactly once", 40, |g| {
        let ds = SyntheticSpec::tiny().generate(g.case_seed);
        let n_sim = g.usize_in(20, 400);
        let n_dis = g.usize_in(20, 400);
        let mut rng = Pcg32::new(g.case_seed ^ 1);
        let pairs = PairSet::sample(&ds, n_sim, n_dis, &mut rng);
        let p = g.usize_in(1, 8.min(n_sim).min(n_dis));
        let shards = partition_pairs(&pairs, p, g.case_seed).unwrap();
        let total: usize = shards.iter().map(|s| s.pairs.len()).sum();
        assert_eq!(total, pairs.len());
        // balance
        let sizes: Vec<usize> =
            shards.iter().map(|s| s.pairs.similar.len()).collect();
        let (mn, mx) =
            (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "unbalanced {sizes:?}");
    });
}

#[test]
fn prop_pair_labels_respected() {
    forall("sampled pairs respect class labels", 30, |g| {
        let mut spec = SyntheticSpec::tiny();
        spec.n_classes = g.usize_in(2, 8);
        let ds = spec.generate(g.case_seed);
        let mut rng = Pcg32::new(g.case_seed ^ 2);
        let pairs = PairSet::sample(&ds, 100, 100, &mut rng);
        assert!(pairs.check_labels(&ds));
    });
}

#[test]
fn prop_objective_nonnegative_and_bounded_by_lambda_at_zero() {
    forall("f(0) == lambda (all hinges active, sim term zero)", 30, |g| {
        let d = g.usize_in(2, 32);
        let k = g.usize_in(1, d);
        let bs = g.usize_in(1, 8);
        let bd = g.usize_in(1, 8);
        let lambda = g.f64_in(0.1, 4.0) as f32;
        let l = Mat::zeros(k, d);
        let ds = g.vec_f32(bs * d, 1.0);
        let dd = g.vec_f32(bd * d, 1.0);
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
        let mut eng = NativeEngine::new();
        let mut grad = Mat::zeros(k, d);
        let f = eng.loss_grad(&l, &batch, lambda, &mut grad).unwrap();
        assert!((f - lambda).abs() < 1e-5 * (1.0 + lambda));
    });
}

#[test]
fn prop_gradient_is_descent_direction() {
    forall("one small step along -grad does not increase f", 25, |g| {
        let d = g.usize_in(4, 24);
        let k = g.usize_in(2, d);
        let bs = g.usize_in(2, 8);
        let bd = g.usize_in(2, 8);
        let mut l = Mat::zeros(k, d);
        let scale = g.f64_in(0.05, 0.5) as f32;
        for v in l.data.iter_mut() {
            *v = g.gaussian_f32(0.0, scale);
        }
        let ds = g.vec_f32(bs * d, 1.0);
        let dd = g.vec_f32(bd * d, 1.0);
        let mut eng = NativeEngine::new();
        let mut grad = Mat::zeros(k, d);
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
        let f0 = eng.loss_grad(&l, &batch, 1.0, &mut grad).unwrap();
        let gnorm = grad.fro_norm();
        if gnorm < 1e-6 {
            return; // flat point (all hinges exactly off) — fine
        }
        let eps = 1e-3 / gnorm;
        l.axpy_inplace(-eps, &grad);
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
        let f1 = eng.loss_grad(&l, &batch, 1.0, &mut grad).unwrap();
        assert!(f1 <= f0 + 1e-5, "f0={f0} f1={f1}");
    });
}

#[test]
fn prop_pair_dist_matches_mahalanobis_identity() {
    forall("‖LΔ‖² == Δᵀ(LᵀL)Δ", 25, |g| {
        let d = g.usize_in(2, 20);
        let k = g.usize_in(1, d);
        let b = g.usize_in(1, 10);
        let mut l = Mat::zeros(k, d);
        for v in l.data.iter_mut() {
            *v = g.gaussian_f32(0.0, 0.5);
        }
        let mut diffs = Mat::zeros(b, d);
        for v in diffs.data.iter_mut() {
            *v = g.gaussian_f32(0.0, 1.0);
        }
        let mut eng = NativeEngine::new();
        let dist = eng.pair_dist(&l, &diffs).unwrap();
        let m = l.matmul_at(&l);
        for r in 0..b {
            let md = m.matvec(diffs.row(r));
            let want = dmlps::linalg::dot(diffs.row(r), &md);
            assert!((dist[r] - want).abs() < 1e-2 * (1.0 + want.abs()),
                    "{} vs {}", dist[r], want);
        }
    });
}

#[test]
fn prop_sgd_step_is_linear_in_lr() {
    forall("L' = L - lr*G exactly", 25, |g| {
        let d = g.usize_in(2, 16);
        let k = g.usize_in(1, d);
        let bs = g.usize_in(1, 6);
        let problem = DmlProblem::new(d, k, 1.0);
        let l0 = problem.init_l(0.2, g.case_seed);
        let ds = g.vec_f32(bs * d, 1.0);
        let dd = g.vec_f32(bs * d, 1.0);
        let lr = g.f64_in(0.001, 0.2) as f32;
        let mut eng = NativeEngine::new();
        let mut grad = Mat::zeros(k, d);
        let batch = MinibatchRef::new(&ds, &dd, bs, bs, d);
        eng.loss_grad(&l0, &batch, 1.0, &mut grad).unwrap();
        let mut l1 = l0.clone();
        let batch = MinibatchRef::new(&ds, &dd, bs, bs, d);
        eng.step(&mut l1, &batch, 1.0, lr).unwrap();
        let mut want = l0.clone();
        want.axpy_inplace(-lr, &grad);
        assert!(l1.max_abs_diff(&want) < 1e-5);
    });
}
