//! Lab-harness integration suite: matrix expansion arithmetic,
//! aggregation against hand-computed references, order-insensitive
//! NDJSON merging, typo rejection across every lab config surface, the
//! shipped `lab/quick.json`, and an end-to-end tiny run whose merged
//! report must self-diff clean and flag a perturbed copy.

use dmlps::lab::{
    self, cell_key, diff_reports, expand, merge_streams, LabConfig,
    ResultType,
};
use dmlps::util::json::Json;
use dmlps::util::rng::Pcg32;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dmlps-lab-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn expansion_count_is_product_and_order_is_stable() {
    let axes = vec![
        ("a".to_string(), vec![Json::Num(1.0), Json::Num(2.0)]),
        (
            "b".to_string(),
            vec![
                Json::Str("x".into()),
                Json::Str("y".into()),
                Json::Str("z".into()),
            ],
        ),
        ("c".to_string(), vec![Json::Bool(true), Json::Bool(false)]),
    ];
    let cells = expand(&axes);
    assert_eq!(cells.len(), 2 * 3 * 2);
    for (i, c) in cells.iter().enumerate() {
        assert_eq!(c.index, i);
    }
    // first and last cells pin the odometer orientation: the last
    // axis spins fastest
    assert_eq!(cell_key(&cells[0].params), "a=1,b=\"x\",c=true");
    assert_eq!(cell_key(&cells[11].params), "a=2,b=\"z\",c=false");
    assert_eq!(expand(&axes), expand(&axes));
}

/// Average/median agree with a from-scratch reference over random
/// trial populations.
#[test]
fn aggregation_matches_reference() {
    let mut rng = Pcg32::new(77);
    let trials = 7usize;
    let mut vals = vec![0.0f32; trials];
    rng.fill_gaussian(&mut vals, 10.0, 3.0);
    let vals: Vec<f64> = vals.iter().map(|&v| v as f64).collect();

    let cfg = LabConfig::parse(
        &Json::parse(&format!(
            r#"[{{"trials": {trials}}},
                {{"name": "agg", "kind": "train",
                  "params": {{"workers": [1]}}}}]"#
        ))
        .unwrap(),
    )
    .unwrap();
    let exp = &cfg.experiments[0];

    let recs: Vec<Json> = vals
        .iter()
        .enumerate()
        .map(|(t, &v)| {
            Json::obj(vec![
                ("cell", Json::Num(0.0)),
                ("cell_key", Json::Str("workers=1".into())),
                ("trial", Json::Num(t as f64)),
                (
                    "params",
                    Json::obj(vec![("workers", Json::Num(1.0))]),
                ),
                ("start_s", Json::Num(t as f64)),
                ("end_s", Json::Num(t as f64 + 0.1)),
                (
                    "metrics",
                    Json::obj(vec![("score", Json::Num(v))]),
                ),
                ("resource_start", Json::obj(vec![])),
                ("resource_end", Json::obj(vec![])),
            ])
        })
        .collect();
    let out = merge_streams(
        exp,
        &[ResultType::Average, ResultType::Median],
        &recs,
        &[],
    )
    .unwrap();

    let mean_ref = vals.iter().sum::<f64>() / trials as f64;
    let mut sorted = vals.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ref = sorted[trials / 2];

    let cell = out.get("cells").idx(0);
    let mean = cell.get("average").get("score").as_f64().unwrap();
    let median = cell.get("median").get("score").as_f64().unwrap();
    assert!((mean - mean_ref).abs() < 1e-9, "{mean} vs {mean_ref}");
    assert!(
        (median - median_ref).abs() < 1e-9,
        "{median} vs {median_ref}"
    );
}

#[test]
fn unknown_lab_keys_are_rejected_with_suggestions() {
    // global typo
    let j = Json::parse(
        r#"[{"trails": 2}, {"name": "x", "params": {}}]"#,
    )
    .unwrap();
    let msg = LabConfig::parse(&j).unwrap_err().to_string();
    assert!(msg.contains("did you mean 'trials'"), "{msg}");

    // experiment-block typo
    let j = Json::parse(
        r#"[{}, {"name": "x", "parms": {"workers": [1]}}]"#,
    )
    .unwrap();
    let msg = LabConfig::parse(&j).unwrap_err().to_string();
    assert!(msg.contains("did you mean 'params'"), "{msg}");

    // axis typo, kind-specific suggestion
    let j = Json::parse(
        r#"[{}, {"name": "x", "kind": "serving",
             "params": {"nclstrs": [8]}}]"#,
    )
    .unwrap();
    let msg = LabConfig::parse(&j).unwrap_err().to_string();
    assert!(msg.contains("did you mean 'nclusters'"), "{msg}");
}

/// The shipped CI config must satisfy the acceptance shape: the first
/// experiment expands to >= 8 cells across >= 3 axes.
#[test]
fn shipped_quick_config_parses_with_required_shape() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("lab/quick.json");
    let cfg = LabConfig::load(&path).unwrap();
    assert!(cfg.experiments.len() >= 3, "{}", cfg.experiments.len());
    let first = &cfg.experiments[0];
    assert!(
        first.axes.len() >= 3,
        "first experiment sweeps {} axes",
        first.axes.len()
    );
    let cells = expand(&first.axes);
    assert!(cells.len() >= 8, "first experiment has {} cells",
            cells.len());

    let full = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("lab/full.json");
    LabConfig::load(&full).unwrap();
}

/// End-to-end: run a two-cell tiny train matrix through the real
/// runner, check the merged report (details + per-cell resource
/// stats), then the diff gate both ways — clean against itself,
/// nonzero drift count against a perturbed copy.
#[test]
fn end_to_end_run_merge_and_diff() {
    let dir = tmp_dir("e2e");
    let cfg = LabConfig::parse(
        &Json::parse(&format!(
            r#"[{{"output": "{}",
                 "result_type": ["average", "details"],
                 "trials": 1, "sample_ms": 10}},
                {{"name": "e2e", "kind": "train", "preset": "tiny",
                  "overrides": {{"steps": 5}},
                  "params": {{"workers": [1, 2]}}}}]"#,
            dir.display()
        ))
        .unwrap(),
    )
    .unwrap();

    let written = lab::run(&cfg).unwrap();
    assert_eq!(written.len(), 1);
    let report = Json::parse_file(&written[0]).unwrap();
    assert_eq!(report.get("bench").as_str(), Some("lab"));
    let cells = report.get("cells").as_arr().unwrap();
    assert_eq!(cells.len(), 2);
    for cell in cells {
        let avg = cell.get("average");
        assert!(avg.get("applied_updates").as_f64().unwrap() > 0.0);
        assert!(avg.get("final_objective").as_f64().unwrap().is_finite());
        let details = cell.get("details").as_arr().unwrap();
        assert_eq!(details.len(), 1);
        let res = cell.get("resource");
        assert!(!res.is_null());
        // cumulative counters are windowed deltas, so >= 0 when present
        if let Some(cpu) = res.get("cpu_s").as_f64() {
            assert!(cpu >= 0.0, "{cpu}");
        }
        #[cfg(target_os = "linux")]
        {
            assert!(
                res.get("peak_rss_bytes").as_f64().unwrap() > 0.0,
                "peak RSS must be attributed on linux"
            );
            assert!(res.get("cpu_s").as_f64().is_some());
        }
    }
    // the NDJSON streams stay on disk next to the merged report
    assert!(dir.join("e2e.trials.ndjson").is_file());
    assert!(dir.join("e2e.sysinfo.ndjson").is_file());

    // self-diff: clean at zero tolerance
    assert!(diff_reports(&report, &report, 0.0, true).is_empty());

    // perturb one metric beyond tolerance: the gate must trip
    let mut perturbed = report.clone();
    if let Json::Obj(map) = &mut perturbed {
        if let Some(Json::Arr(cells)) = map.get_mut("cells") {
            if let Json::Obj(cell) = &mut cells[0] {
                if let Some(Json::Obj(avg)) = cell.get_mut("average") {
                    if let Some(Json::Num(v)) =
                        avg.get_mut("applied_updates")
                    {
                        *v *= 10.0;
                    }
                }
            }
        }
    }
    let drifts = diff_reports(&report, &perturbed, 0.25, false);
    assert!(!drifts.is_empty());
    assert!(
        drifts.iter().any(|d| d.contains("applied_updates")),
        "{drifts:?}"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// Shuffling the trial stream does not change the merged report
/// (order-insensitive merge over real runner records).
#[test]
fn merge_is_order_insensitive_over_real_records() {
    let dir = tmp_dir("shuffle");
    let cfg = LabConfig::parse(
        &Json::parse(&format!(
            r#"[{{"output": "{}",
                 "result_type": ["average", "median", "details"],
                 "trials": 2, "sample_ms": 10}},
                {{"name": "shf", "kind": "hotpath",
                  "overrides": {{"d": 32, "k": 8, "batch": 16}},
                  "params": {{"threads": [1, 2]}}}}]"#,
            dir.display()
        ))
        .unwrap(),
    )
    .unwrap();
    lab::run(&cfg).unwrap();

    let exp = &cfg.experiments[0];
    let recs: Vec<Json> = std::fs::read_to_string(
        dir.join("shf.trials.ndjson"),
    )
    .unwrap()
    .lines()
    .map(|l| Json::parse(l).unwrap())
    .collect();
    assert_eq!(recs.len(), 4); // 2 cells × 2 trials
    let mut reversed = recs.clone();
    reversed.reverse();
    let rt = &cfg.global.result_types;
    let a = merge_streams(exp, rt, &recs, &[]).unwrap();
    let b = merge_streams(exp, rt, &reversed, &[]).unwrap();
    assert_eq!(a.to_string_pretty(), b.to_string_pretty());

    let _ = std::fs::remove_dir_all(&dir);
}
