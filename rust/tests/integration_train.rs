//! Integration: full train→save→load→eval round trip through the public
//! API (what `dmlps train --save-model` + `dmlps eval` do).

use dmlps::cli::driver::{ap_euclidean, ap_of_l, train_single_thread};
use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::dml::NativeEngine;

#[test]
fn train_save_load_eval_roundtrip() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 600;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let mut eng = NativeEngine::new();
    let run = train_single_thread(&cfg, &data, &mut eng, 600).unwrap();
    let ap1 = ap_of_l(&mut eng, &run.l, &data).unwrap();
    assert!(ap1 > ap_euclidean(&data), "must beat Euclidean");

    let path = std::env::temp_dir().join("dmlps_it_model.bin");
    run.l.save(&path).unwrap();
    let l2 = dmlps::linalg::Mat::load(&path).unwrap();
    assert_eq!(run.l, l2);
    let ap2 = ap_of_l(&mut eng, &l2, &data).unwrap();
    assert_eq!(ap1, ap2);
}

#[test]
fn curves_are_monotone_in_time_and_steps() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 200;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let mut eng = NativeEngine::new();
    let run = train_single_thread(&cfg, &data, &mut eng, 40).unwrap();
    for w in run.curve.points.windows(2) {
        assert!(w[1].time_s >= w[0].time_s);
        assert!(w[1].step >= w[0].step);
    }
    assert!(run.curve.points.len() >= 3);
}

#[test]
fn deterministic_given_seed() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 100;
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let mut e1 = NativeEngine::new();
    let r1 = train_single_thread(&cfg, &data, &mut e1, 100).unwrap();
    let mut e2 = NativeEngine::new();
    let r2 = train_single_thread(&cfg, &data, &mut e2, 100).unwrap();
    assert_eq!(r1.l, r2.l);
}
