//! Integration: full train→save→load→eval round trip through the public
//! API (what `dmlps train --save-model` + `dmlps eval` do), on the
//! `Session` → `MetricModel` surface.

use std::sync::Arc;

use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::dml::NativeEngine;
use dmlps::eval::{ap_euclidean, ap_of_l};
use dmlps::session::{MetricModel, Session};

#[test]
fn train_save_load_eval_roundtrip() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 600;
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let run = Session::from_config(cfg)
        .data(data.clone())
        .probe(600, (500, 500))
        .train_sequential()
        .unwrap();
    let model = run.into_model().unwrap();
    let mut eng = NativeEngine::new();
    let ap1 = ap_of_l(&mut eng, model.l(), &data).unwrap();
    assert!(ap1 > ap_euclidean(&data), "must beat Euclidean");

    let path = std::env::temp_dir().join("dmlps_it_model.bin");
    model.save(&path).unwrap();
    let served = MetricModel::load(&path).unwrap();
    assert_eq!(model, served);
    let ap2 = ap_of_l(&mut eng, served.l(), &data).unwrap();
    assert_eq!(ap1, ap2);
}

#[test]
fn curves_are_monotone_in_time_and_steps() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 200;
    let run = Session::from_config(cfg)
        .probe(40, (500, 500))
        .train_sequential()
        .unwrap();
    for w in run.curve.points.windows(2) {
        assert!(w[1].time_s >= w[0].time_s);
        assert!(w[1].step >= w[0].step);
    }
    assert!(run.curve.points.len() >= 3);
}

#[test]
fn deterministic_given_seed() {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = 100;
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let session = Session::from_config(cfg)
        .data(data)
        .probe(100, (500, 500));
    let r1 = session.train_sequential().unwrap();
    let r2 = session.train_sequential().unwrap();
    assert_eq!(
        r1.require_model().unwrap().l(),
        r2.require_model().unwrap().l()
    );
}
