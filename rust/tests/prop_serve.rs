//! Property suite for the retrieval serving layer (`dmlps::serve`).
//!
//! The contracts pinned here are the ones the ISSUE names:
//!
//! 1. the approximate path's recall@10 at the benched `nprobe` default
//!    stays above the 0.9 floor;
//! 2. `nprobe = nclusters` is **bit-for-bit** identical to the exact
//!    scan — the approximate path is a candidate filter in front of the
//!    same heap, never a different kernel;
//! 3. batched answers equal one-at-a-time answers bitwise (one gemm
//!    path for both);
//! 4. hot-swapping models under hammering readers never yields a torn
//!    response: every answer is consistent with exactly one version,
//!    and versions observed on one connection never go backwards.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dmlps::config::Preset;
use dmlps::data::{Dataset, SyntheticSpec};
use dmlps::linalg::Mat;
use dmlps::serve::{default_nprobe, ScanMode, ServeConfig, ServeEngine};
use dmlps::session::MetricModel;
use dmlps::util::rng::Pcg32;

fn model_with_seed(seed: u64, kproj: usize, dim: usize) -> MetricModel {
    let mut l = Mat::zeros(kproj, dim);
    Pcg32::new(seed).fill_gaussian(&mut l.data, 0.0, 0.3);
    MetricModel::new(l, &Preset::Tiny.config())
}

/// A gallery of `n_classes` far-apart, tight clusters: class centers
/// drawn at scale 10, per-row noise at scale 0.3. Every row's true
/// neighbors are its own cluster by a huge margin, so approximate
/// recall has a clean ground truth.
fn tight_clusters(
    seed: u64,
    n: usize,
    dim: usize,
    n_classes: usize,
) -> Dataset {
    let mut rng = Pcg32::new(seed);
    let mut centers = Mat::zeros(n_classes, dim);
    rng.fill_gaussian(&mut centers.data, 0.0, 10.0);
    let mut x = Mat::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    for r in 0..n {
        let c = r % n_classes;
        labels.push(c as u32);
        let mut noise = vec![0.0f32; dim];
        rng.fill_gaussian(&mut noise, 0.0, 0.3);
        for (j, v) in x.row_mut(r).iter_mut().enumerate() {
            *v = centers.at(c, j) + noise[j];
        }
    }
    Dataset { x, labels, n_classes }
}

fn assert_rows_bitwise(
    got: &[Vec<(u32, f32)>],
    want: &[Vec<(u32, f32)>],
    ctx: &str,
) {
    assert_eq!(got.len(), want.len(), "{ctx}: row count");
    for (r, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{ctx}: row {r} hit count");
        for (t, (&(gi, gd), &(wi, wd))) in g.iter().zip(w).enumerate() {
            assert_eq!(gi, wi, "{ctx}: row {r} hit {t} index");
            assert_eq!(
                gd.to_bits(),
                wd.to_bits(),
                "{ctx}: row {r} hit {t} distance bits"
            );
        }
    }
}

#[test]
fn approx_recall_at_default_nprobe_meets_floor() {
    let nclusters = 16;
    let gallery = tight_clusters(31, 1024, 32, nclusters);
    let engine = ServeEngine::new(
        model_with_seed(1, 8, 32),
        &gallery,
        ServeConfig { nclusters, ..ServeConfig::default() },
    );
    let nprobe = default_nprobe(nclusters);
    assert!(nprobe < nclusters, "default must actually prune");
    let k = 10;
    let (mut hit, mut denom) = (0usize, 0usize);
    for r in 0..200 {
        let q = gallery.feature(r).to_vec();
        let (_, exact) = engine.query_one(&q, k, ScanMode::Exact);
        let (_, approx) = engine.query_one(&q, k, ScanMode::Probe(nprobe));
        denom += exact.len();
        hit += approx
            .iter()
            .filter(|(i, _)| exact.iter().any(|(j, _)| j == i))
            .count();
    }
    let recall = hit as f64 / denom as f64;
    assert!(
        recall >= 0.9,
        "recall@{k} = {recall:.4} at nprobe={nprobe} (floor 0.9)"
    );
}

#[test]
fn nprobe_equals_nclusters_is_bitwise_exact() {
    for seed in [3u64, 17, 40] {
        let gallery = SyntheticSpec::tiny().generate(seed);
        let nclusters = 8;
        let engine = ServeEngine::new(
            model_with_seed(seed + 100, 8, gallery.dim()),
            &gallery,
            ServeConfig { nclusters, ..ServeConfig::default() },
        );
        // both a clean k and k > gallery (the centralized clamp path)
        for k in [5usize, 5000] {
            for r in 0..32 {
                let q = gallery.feature(r * 7 % gallery.n()).to_vec();
                let (_, exact) = engine.query_one(&q, k, ScanMode::Exact);
                let (_, full_probe) =
                    engine.query_one(&q, k, ScanMode::Probe(nclusters));
                assert_rows_bitwise(
                    std::slice::from_ref(&full_probe),
                    std::slice::from_ref(&exact),
                    &format!("seed {seed} k {k} query {r}"),
                );
            }
        }
    }
}

#[test]
fn batched_equals_one_at_a_time_bitwise() {
    let gallery = SyntheticSpec::tiny().generate(23);
    let engine = ServeEngine::new(
        model_with_seed(9, 8, gallery.dim()),
        &gallery,
        ServeConfig { nclusters: 8, ..ServeConfig::default() },
    );
    let b = 16;
    let mut x = Mat::zeros(b, gallery.dim());
    for r in 0..b {
        x.row_mut(r).copy_from_slice(gallery.feature(r * 11));
    }
    for mode in [ScanMode::Exact, ScanMode::Probe(2)] {
        let batch = engine.query_batch(&x, 5, mode);
        for r in 0..b {
            let (_, one) = engine.query_one(x.row(r), 5, mode);
            assert_rows_bitwise(
                std::slice::from_ref(&one),
                std::slice::from_ref(&batch.results[r]),
                &format!("mode {mode:?} row {r}"),
            );
        }
    }
}

/// ≥ 100 hot-swaps between two models while reader threads hammer the
/// engine. Every response must be *exactly* the answer its version's
/// model gives — any mix of old projection with new quantizer (or any
/// other tear) produces different bytes and fails. Versions observed by
/// one reader must also never decrease.
#[test]
fn hot_swap_under_hammering_readers_never_tears() {
    let gallery = Arc::new(SyntheticSpec::tiny().generate(5));
    let dim = gallery.dim();
    let cfg = ServeConfig { nclusters: 8, ..ServeConfig::default() };
    let model_a = model_with_seed(111, 8, dim);
    let model_b = model_with_seed(222, 8, dim);

    let b = 4;
    let k = 5;
    let mut x = Mat::zeros(b, dim);
    for r in 0..b {
        x.row_mut(r).copy_from_slice(gallery.feature(r * 13));
    }

    // reference answers, one per model, computed on throwaway engines
    // (epoch construction is a pure function of (model, gallery, cfg))
    let expect_a = ServeEngine::new(model_a.clone(), &gallery, cfg)
        .query_batch(&x, k, ScanMode::Exact)
        .results;
    let expect_b = ServeEngine::new(model_b.clone(), &gallery, cfg)
        .query_batch(&x, k, ScanMode::Exact)
        .results;
    assert_ne!(
        expect_a, expect_b,
        "the two models must disagree or tearing is undetectable"
    );

    // v1 = A, then swaps alternate B, A, B, ... → odd versions are A
    let engine = Arc::new(ServeEngine::new(model_a.clone(), &gallery, cfg));
    let stop = Arc::new(AtomicBool::new(false));
    let swaps = 120u64;

    std::thread::scope(|s| {
        let mut readers = Vec::new();
        for _ in 0..4 {
            let engine = Arc::clone(&engine);
            let stop = Arc::clone(&stop);
            let (x, expect_a, expect_b) = (&x, &expect_a, &expect_b);
            readers.push(s.spawn(move || {
                let mut seen = 0u64;
                let mut last_version = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let ans = engine.query_batch(x, k, ScanMode::Exact);
                    assert!(
                        ans.version >= last_version,
                        "version went backwards: {} -> {}",
                        last_version,
                        ans.version
                    );
                    last_version = ans.version;
                    let want = if ans.version % 2 == 1 {
                        expect_a
                    } else {
                        expect_b
                    };
                    assert_rows_bitwise(
                        &ans.results,
                        want,
                        &format!("version {}", ans.version),
                    );
                    seen += 1;
                }
                seen
            }));
        }

        for i in 0..swaps {
            let next = if i % 2 == 0 { &model_b } else { &model_a };
            engine.swap(next.clone(), &gallery);
        }
        stop.store(true, Ordering::Relaxed);
        let total: u64 = readers
            .into_iter()
            .map(|r| r.join().expect("reader panicked (torn read?)"))
            .sum();
        assert!(total > 0, "readers never completed a query");
    });

    assert_eq!(engine.stats().swaps, swaps);
    assert_eq!(engine.snapshot().version(), 1 + swaps);
}
