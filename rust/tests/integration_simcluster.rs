//! Integration: the cluster simulator reproduces the paper's qualitative
//! scalability claims on a small numeric workload.

use dmlps::cli::driver::{simulate_convergence, SimKnobs};
use dmlps::config::Preset;
use dmlps::data::ExperimentData;

fn cfg() -> dmlps::config::ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.n_similar = 2_000;
    cfg.dataset.n_dissimilar = 2_000;
    cfg.optim.batch_sim = 8;
    cfg.optim.batch_dis = 8;
    cfg
}

#[test]
fn more_cores_converge_faster_in_sim_time() {
    let cfg = cfg();
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let knobs = |u| SimKnobs {
        grad_seconds: 0.5, // compute-dominated regime (paper's)
        bytes_per_msg: None,
        total_updates: u,
        ..SimKnobs::default()
    };
    let t1 = simulate_convergence(&cfg, &data, 1, 16, knobs(300)).unwrap();
    let t4 = simulate_convergence(&cfg, &data, 4, 16, knobs(300)).unwrap();
    assert!(t4.sim_seconds < t1.sim_seconds * 0.35,
            "4 machines {} vs 1 machine {}", t4.sim_seconds,
            t1.sim_seconds);
    // both make real optimization progress
    for r in [&t1, &t4] {
        let first = r.curve.points.first().unwrap().objective;
        let last = r.curve.points.last().unwrap().objective;
        assert!(last < first, "{first} -> {last}");
    }
}

#[test]
fn simulated_objective_tracks_serial_quality() {
    // 1 machine x 1 core with instant network == plain serial SGD;
    // the simulated curve must descend like the real thing.
    let cfg = cfg();
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let r = simulate_convergence(&cfg, &data, 1, 1, SimKnobs {
        grad_seconds: 0.1,
        bytes_per_msg: None,
        total_updates: 400,
        ..SimKnobs::default()
    }).unwrap();
    let first = r.curve.points.first().unwrap().objective;
    let last = r.curve.points.last().unwrap().objective;
    assert!(last < first * 0.8, "{first} -> {last}");
    assert!((r.sim_seconds - 40.0).abs() < 5.0,
            "serial time should be ~updates*grad_seconds: {}",
            r.sim_seconds);
}
