//! Elasticity — convergence vs disruption.
//!
//! One mid-run cluster death is injected into the simulated-cluster
//! protocol (the same real-numerics machinery as fig2) under different
//! checkpoint cadences, against an undisturbed baseline. The curves
//! quantify what the checkpoint/restore layer buys: with a tight
//! cadence the restart costs little more than the restart delay; with
//! no checkpoints the run falls back to the initial parameters and
//! re-pays everything.
//!
//! Writes **`BENCH_elastic.json`** (override the path with
//! `DMLPS_BENCH_OUT`): per-scenario convergence curves (sim time ×
//! applied updates × objective), updates re-done after the rollback,
//! and time-to-target against the undisturbed baseline's final
//! objective. `DMLPS_BENCH_QUICK=1` shrinks the sweep for CI.

use std::sync::Arc;

use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::metrics::Curve;
use dmlps::session::{calibrate_for, sim_scaled, Session, SimKnobs};
use dmlps::simcluster::Disruption;
use dmlps::util::json::Json;

fn curve_json(c: &Curve) -> Json {
    Json::Arr(
        c.points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("time_s", Json::Num(p.time_s)),
                    ("updates", Json::Num(p.step as f64)),
                    ("objective", Json::Num(p.objective)),
                ])
            })
            .collect(),
    )
}

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let updates: u64 = if quick { 300 } else { 1_500 };
    let kill_at = updates / 2;
    let restart_delay_s = 5.0;

    let scaled = sim_scaled(Preset::Mnist);
    let cfg = &scaled.cfg;
    let data = Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let grad_seconds = calibrate_for(cfg);

    let disrupt = |every: u64| {
        Some(Disruption {
            kill_at_update: kill_at,
            restart_delay_s,
            ckpt_every_updates: every,
        })
    };
    let scenarios: Vec<(&str, Option<Disruption>)> = vec![
        ("undisturbed", None),
        ("kill_ckpt_every_25", disrupt(25)),
        ("kill_ckpt_every_100", disrupt(100)),
        ("kill_no_checkpoint", disrupt(0)),
    ];

    println!(
        "# Elastic recovery: kill at update {kill_at} of {updates}, \
         restart after {restart_delay_s} sim-s\n"
    );
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for (name, disruption) in &scenarios {
        let r = Session::from_config(cfg.clone())
            .data(data.clone())
            .topology(2, 4)
            .sim_knobs(SimKnobs {
                grad_seconds,
                bytes_per_msg: None,
                total_updates: updates,
                disruption: *disruption,
            })
            .simulate()
            .expect("simulated run");
        println!(
            "  {name:<22} {:>8.1} sim-s, {} restarts, {:>4} updates \
             re-done, final f = {:.4}",
            r.sim_seconds, r.restarts, r.redone_updates,
            r.curve.final_objective().unwrap_or(f64::NAN),
        );
        results.push((*name, r));
    }

    // time-to-target: the undisturbed run's final objective (§5.3 style)
    let target = results[0].1.curve.final_objective().unwrap();
    println!("\n| scenario | time-to-target (sim-s) | overhead |");
    println!("|---|---|---|");
    let base_t = results[0].1.curve.time_to_reach(target);
    for (name, r) in &results {
        let t = r.curve.time_to_reach(target);
        let overhead = match (base_t, t) {
            (Some(b), Some(t)) if b > 0.0 => {
                format!("{:+.1}%", (t / b - 1.0) * 100.0)
            }
            _ => "n/a".into(),
        };
        println!(
            "| {name} | {} | {overhead} |",
            t.map_or("never".into(), |t| format!("{t:.1}")),
        );
        rows.push(Json::obj(vec![
            ("scenario", Json::Str((*name).to_string())),
            ("sim_seconds", Json::Num(r.sim_seconds)),
            ("restarts", Json::Num(r.restarts as f64)),
            ("redone_updates", Json::Num(r.redone_updates as f64)),
            // null (not NaN) when no probe landed / the target was
            // never reached — "missing" is valid, garbage is not, and
            // the finite guard must only refuse the latter
            ("final_objective",
             r.curve.final_objective().map(Json::Num)
                 .unwrap_or(Json::Null)),
            ("time_to_target_s",
             t.map(Json::Num).unwrap_or(Json::Null)),
            ("curve", curve_json(&r.curve)),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("elastic_recovery".into())),
        ("quick", Json::Num(if quick { 1.0 } else { 0.0 })),
        ("total_updates", Json::Num(updates as f64)),
        ("kill_at_update", Json::Num(kill_at as f64)),
        ("restart_delay_s", Json::Num(restart_delay_s)),
        ("target_objective", Json::Num(target)),
        ("scenarios", Json::Arr(rows)),
    ]);
    match dmlps::metrics::write_bench_json("BENCH_elastic.json", &out) {
        Ok(path) => println!(
            "\nwrote machine-readable baseline to {}",
            path.display()
        ),
        Err(e) => {
            eprintln!("ERROR: {e}");
            std::process::exit(1);
        }
    }
}
