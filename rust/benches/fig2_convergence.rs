//! Fig 2 — convergence curves under different numbers of CPU cores.
//!
//! For each of the paper's three datasets we sweep the paper's core
//! range on the discrete-event cluster simulator: the *numerics* (real
//! async-SGD gradients, real staleness) run at a dimension-scaled shape,
//! while the simulated clock charges each gradient the FLOP-extrapolated
//! paper-true cost and each message the paper-true parameter bytes — so
//! the time axis is faithful to the paper's hardware model.
//!
//! Expected shape (paper §5.3): "increasing the number of machines
//! consistently increases the convergence speed".

use std::sync::Arc;

use dmlps::session::{calibrate_for, sim_scaled, Session, SimKnobs};

/// Era calibration: the paper's 2014 testbed retires the minibatch
/// gradient ~10x slower than this box's single core (anchor: the paper
/// reports ~0.5 h single-thread MNIST training in section 5.4; ours measures
/// ~2-3 min at the identical shape). The simulated clock charges
/// paper-era cost so compute/communication ratios match the paper's.
const ERA_SLOWDOWN: f64 = 10.0;
use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::metrics::curves_to_markdown;

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let updates: u64 = if quick { 200 } else { 600 };

    // (figure, preset, cores-per-machine, total-core sweep)
    let sweeps: [(&str, Preset, usize, &[usize]); 3] = [
        ("Fig 2(a) MNIST", Preset::Mnist, 16,
         &[16, 32, 64, 128, 256]),
        ("Fig 2(b) ImageNet-63K", Preset::Imnet60kScaled, 64,
         &[64, 128, 256]),
        ("Fig 2(c) ImageNet-1M", Preset::Imnet1mScaled, 64,
         &[64, 128, 256]),
    ];

    for (title, preset, cpm, cores_list) in sweeps {
        let scaled = sim_scaled(preset);
        let cfg = &scaled.cfg;
        let data =
            Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
        let grad_scaled = calibrate_for(cfg);
        let grad_paper = grad_scaled * scaled.flop_ratio * ERA_SLOWDOWN;
        println!(
            "\n# {title}\n\nnumerics at d={} k={} (scaled), clock at \
             paper-true cost {:.3}s/grad/core, message {:.1} MB",
            cfg.dataset.dim, cfg.model.k, grad_paper,
            scaled.paper_bytes / 1e6
        );
        let mut curves = Vec::new();
        for &cores in cores_list {
            let machines = (cores / cpm).max(1);
            let r = Session::from_config(cfg.clone())
                .data(data.clone())
                .topology(machines, cpm.min(cores))
                .sim_knobs(SimKnobs {
                    grad_seconds: grad_paper,
                    bytes_per_msg: Some(scaled.paper_bytes),
                    total_updates: updates,
                    ..SimKnobs::default()
                })
                .simulate()
                .expect("simulated run");
            println!(
                "  {cores:>4} cores: {:>9.1} sim-s to {updates} updates, \
                 staleness {:>6.1}, final f = {:.4}",
                r.sim_seconds, r.mean_staleness,
                r.curve.final_objective().unwrap_or(f64::NAN)
            );
            curves.push(r.curve);
        }
        println!("{}", curves_to_markdown(&curves, 12));
        // the paper's claim: more cores → faster convergence in time.
        // check: time to reach the slowest setting's final objective
        let target = curves[0].final_objective().unwrap();
        print!("time to reach f≤{target:.4}:");
        for c in &curves {
            match c.time_to_reach(target) {
                Some(t) => print!("  {:.0}s", t),
                None => print!("  -"),
            }
        }
        println!();
    }
}
