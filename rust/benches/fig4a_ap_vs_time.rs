//! Fig 4(a) — average precision versus running time on MNIST, all
//! methods, single-threaded (the paper runs every method single-thread
//! MATLAB; we run every method single-thread Rust).
//!
//! The MNIST analog is dimension-scaled (d=64) so Xing2002's O(d³)
//! eigen-projection per iteration completes in bench time — exactly the
//! cost asymmetry the figure is about. Expected shape: ours reaches the
//! best AP fastest; Xing2002 is orders of magnitude slower per unit of
//! quality; ITML is non-monotone; KISS is a fast single point with
//! clearly lower AP; all compared on identical held-out pairs.

use dmlps::cli::driver::ap_traces_all_methods;
use dmlps::config::{FeatureKind, Preset};
use dmlps::data::ExperimentData;

pub fn mnist_small_config() -> dmlps::config::ExperimentConfig {
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.name = "mnist_small".into();
    cfg.dataset.kind = FeatureKind::Gaussian;
    cfg.dataset.dim = 64;
    cfg.dataset.n_classes = 10;
    cfg.dataset.separation = 4.0;
    cfg.dataset.n_train = 2_000;
    cfg.dataset.n_test = 1_000;
    cfg.dataset.n_similar = 5_000;
    cfg.dataset.n_dissimilar = 5_000;
    cfg.dataset.n_test_pairs = 2_000;
    cfg.model.k = 48;
    cfg.model.init_scale = 0.2;
    cfg.optim.steps = 3_000;
    cfg.optim.batch_sim = 16;
    cfg.optim.batch_dis = 16;
    cfg.optim.lr = 0.3;
    cfg.artifact_variant = None;
    cfg
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let mut cfg = mnist_small_config();
    if quick {
        cfg.optim.steps = 500;
    }
    println!(
        "# Fig 4(a): AP vs running time (MNIST analog, d={} k={}, \
         single thread)\n",
        cfg.dataset.dim, cfg.model.k
    );
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let traces = ap_traces_all_methods(
        &cfg,
        &data,
        /*probe_every=*/ if quick { 100 } else { 250 },
        /*xing_iters=*/ if quick { 10 } else { 150 },
        /*itml_sweeps=*/ 2,
    )?;

    for (name, trace) in &traces {
        println!("\n## {name}\n");
        println!("| time (s) | test AP |");
        println!("|---|---|");
        for (t, ap) in trace {
            println!("| {t:.3} | {ap:.4} |");
        }
    }

    println!("\n## summary (best AP & time to reach it)\n");
    println!("| method | best AP | at time (s) |");
    println!("|---|---|---|");
    let mut best_ours = 0.0;
    for (name, trace) in &traces {
        let (t, ap) = trace
            .iter()
            .fold((0.0, 0.0), |acc, &(t, ap)| {
                if ap > acc.1 { (t, ap) } else { acc }
            });
        if name == "ours" {
            best_ours = ap;
        }
        println!("| {name} | {ap:.4} | {t:.3} |");
    }
    // paper claim: ours achieves the best AP of all methods
    for (name, trace) in &traces {
        if name == "ours" || name == "Euclidean" {
            continue;
        }
        let best = trace.iter().map(|&(_, ap)| ap).fold(0.0, f64::max);
        if best > best_ours {
            println!("NOTE: {name} beat ours ({best:.4} > {best_ours:.4})");
        }
    }
    Ok(())
}
