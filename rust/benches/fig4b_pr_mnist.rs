//! Fig 4(b) — best precision-recall curves on MNIST, all methods.
//!
//! Fits each method on the MNIST analog (same config as fig4a), scores
//! the same held-out pairs, and prints each method's PR curve sampled at
//! fixed recall grid points, plus AP. Expected ordering (paper §5.4):
//! ours > Xing2002 ≈ ITML > KISS, all > Euclidean.

use std::sync::Arc;

use dmlps::baselines::{Itml, ItmlConfig, Kiss, KissConfig, LearnedMetric,
                       Xing2002, Xing2002Config};
use dmlps::config::{ExperimentConfig, FeatureKind, Preset};
use dmlps::data::ExperimentData;
use dmlps::dml::NativeEngine;
use dmlps::eval::{average_precision, pr_curve};
use dmlps::session::Session;

fn mnist_small_config() -> ExperimentConfig {
    // keep in sync with fig4a
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.name = "mnist_small".into();
    cfg.dataset.kind = FeatureKind::Gaussian;
    cfg.dataset.dim = 64;
    cfg.dataset.n_classes = 10;
    cfg.dataset.separation = 4.0;
    cfg.dataset.n_train = 2_000;
    cfg.dataset.n_test = 1_000;
    cfg.dataset.n_similar = 5_000;
    cfg.dataset.n_dissimilar = 5_000;
    cfg.dataset.n_test_pairs = 2_000;
    cfg.model.k = 48;
    cfg.model.init_scale = 0.2;
    cfg.optim.steps = 3_000;
    cfg.optim.batch_sim = 16;
    cfg.optim.batch_dis = 16;
    cfg.optim.lr = 0.3;
    cfg.artifact_variant = None;
    cfg
}

/// Sample a PR curve at a fixed recall grid for table display.
fn sample_pr(sim: &[f32], dis: &[f32]) -> Vec<(f64, f64)> {
    let curve = pr_curve(sim, dis);
    let grid: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
    grid.iter()
        .map(|&r| {
            let p = curve
                .iter()
                .find(|pt| pt.recall >= r)
                .map(|pt| pt.precision)
                .unwrap_or(f64::NAN);
            (r, p)
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let mut cfg = mnist_small_config();
    if quick {
        cfg.optim.steps = 500;
    }
    println!("# Fig 4(b): precision-recall curves on MNIST analog\n");
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));

    let mut results: Vec<(String, Vec<f32>, Vec<f32>)> = Vec::new();

    // ours
    let run = Session::from_config(cfg.clone())
        .data(data.clone())
        .probe(10_000, (500, 500))
        .train_sequential()?;
    let mut engine = NativeEngine::new();
    let (sim, dis) = dmlps::eval::score_pairs(
        &mut engine, run.l()?, &data.test, &data.test_pairs,
    )?;
    results.push(("ours".into(), sim, dis));

    // Xing2002
    let x = Xing2002::new(Xing2002Config {
        iters: if quick { 10 } else { 40 },
        ..Default::default()
    });
    let (m, _) = x.fit_traced(&data.train, &data.pairs, &data.test,
                              &data.test_pairs);
    let (sim, dis) = m.score(&data.test, &data.test_pairs);
    results.push(("Xing2002".into(), sim, dis));

    // ITML
    let itml = Itml::new(ItmlConfig { sweeps: 2, ..Default::default() });
    let (m, _) = itml.fit_traced(&data.train, &data.pairs, &data.test,
                                 &data.test_pairs);
    let (sim, dis) = m.score(&data.test, &data.test_pairs);
    results.push(("ITML".into(), sim, dis));

    // KISS
    let kiss = Kiss::new(KissConfig {
        pca_dim: 64,
        ..Default::default()
    });
    let m = kiss.fit(&data.train, &data.pairs);
    let (sim, dis) = m.score(&data.test, &data.test_pairs);
    results.push(("KISS".into(), sim, dis));

    // Euclidean
    let (sim, dis) = LearnedMetric::Euclidean
        .score(&data.test, &data.test_pairs);
    results.push(("Euclidean".into(), sim, dis));

    println!("| recall | {} |",
             results.iter().map(|(n, _, _)| n.clone())
                 .collect::<Vec<_>>().join(" | "));
    println!("|{}|", "---|".repeat(results.len() + 1));
    let curves: Vec<Vec<(f64, f64)>> = results
        .iter()
        .map(|(_, s, d)| sample_pr(s, d))
        .collect();
    for i in 0..10 {
        print!("| {:.1} ", curves[0][i].0);
        for c in &curves {
            print!("| {:.4} ", c[i].1);
        }
        println!("|");
    }
    println!("\n| method | AP |");
    println!("|---|---|");
    for (name, sim, dis) in &results {
        println!("| {name} | {:.4} |", average_precision(sim, dis));
    }
    Ok(())
}
