//! Ablation: parameter-server sharding (`server_shards` knob).
//!
//! Trains the paper's MNIST shape (k=600, d=780 → 1.87 MB of f32
//! parameters) with the real threaded server at S ∈ {1, 2, 4} shards and
//! records the messaging profile: per-message bytes (the quantity
//! sharding divides by S), physical message counts, and applied
//! (logical) updates per second. Writes the machine-readable baseline to
//! **`BENCH_ps.json`** (override the path with `DMLPS_BENCH_OUT`).
//!
//! `server_shards = 1` is the paper's single central server, so the S=1
//! row doubles as the anchor for the existing convergence benches.

use std::sync::Arc;

use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::ps::{RunOptions, ShardPlan};
use dmlps::session::Session;
use dmlps::util::json::Json;

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let mut cfg = Preset::Mnist.config();
    // Keep the paper-true k×d message shape; shrink the data volume so
    // the bench measures messaging and folding, not data generation.
    cfg.dataset.n_train = 6_000;
    cfg.dataset.n_test = 500;
    cfg.dataset.n_similar = 20_000;
    cfg.dataset.n_dissimilar = 20_000;
    cfg.dataset.n_test_pairs = 1_000;
    cfg.optim.steps = if quick { 10 } else { 40 };
    cfg.cluster.workers = 2;
    cfg.artifact_variant = None;

    println!(
        "ablation_shards: MNIST shape d={} k={} ({} params, {:.2} MB \
         full message), {} workers × {} steps",
        cfg.dataset.dim,
        cfg.model.k,
        cfg.model.k * cfg.dataset.dim,
        (cfg.model.k * cfg.dataset.dim * 4) as f64 / 1e6,
        cfg.cluster.workers,
        cfg.optim.steps,
    );
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let opts = RunOptions {
        // probe only at the endpoints: the bench times messaging, not
        // objective evaluation
        probe_every: u64::MAX / 2,
        probe_pairs: (50, 50),
        ..Default::default()
    };

    println!(
        "\n| shards | bytes/grad-msg | grad msgs | param msgs | \
         applied | upd/s | wall s |"
    );
    println!("|---|---|---|---|---|---|---|");
    let mut rows: Vec<Json> = Vec::new();
    let mut baseline_ups = 0.0f64;
    for shards in [1usize, 2, 4] {
        let mut c = cfg.clone();
        c.cluster.server_shards = shards;
        let r = Session::from_config(c.clone())
            .engine("native")
            .data(data.clone())
            .run_options(opts.clone())
            .train_distributed()
            .expect("sharded training run");
        let plan = ShardPlan::new(c.model.k, c.dataset.dim, shards);
        // max slice size = per-message payload ceiling
        let bytes_per_grad_msg = (0..plan.shards())
            .map(|s| plan.len(s) * 4)
            .max()
            .unwrap_or(0);
        let grads_logical: u64 =
            r.worker_stats.iter().map(|w| w.grads_sent).sum();
        let grad_msgs = grads_logical * shards as u64;
        let param_msgs = r.param_msgs;
        let ups = r.applied_updates as f64 / r.wall_s.max(1e-9);
        if shards == 1 {
            baseline_ups = ups;
        }
        println!(
            "| {shards} | {} | {grad_msgs} | {param_msgs} | {} | \
             {ups:.1} | {:.2} |",
            bytes_per_grad_msg, r.applied_updates, r.wall_s
        );
        rows.push(Json::obj(vec![
            ("shards", Json::Num(shards as f64)),
            ("bytes_per_grad_msg", Json::Num(bytes_per_grad_msg as f64)),
            ("bytes_per_param_msg",
             Json::Num(bytes_per_grad_msg as f64)),
            ("grad_msgs", Json::Num(grad_msgs as f64)),
            ("param_msgs", Json::Num(param_msgs as f64)),
            ("applied_updates", Json::Num(r.applied_updates as f64)),
            ("slice_updates", Json::Num(r.slice_updates as f64)),
            ("broadcast_rounds", Json::Num(r.broadcasts as f64)),
            ("updates_per_sec", Json::Num(ups)),
            ("wall_s", Json::Num(r.wall_s)),
            ("final_objective",
             Json::Num(r.curve.final_objective().unwrap_or(f64::NAN))),
        ]));
    }
    if baseline_ups > 0.0 {
        println!(
            "\n(S=1 anchor: {baseline_ups:.1} applied updates/s; \
             per-message bytes shrink ~S× by construction)"
        );
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("ablation_shards".into())),
        ("quick", Json::Bool(quick)),
        ("shape", Json::obj(vec![
            ("k", Json::Num(cfg.model.k as f64)),
            ("d", Json::Num(cfg.dataset.dim as f64)),
            ("workers", Json::Num(cfg.cluster.workers as f64)),
            ("steps", Json::Num(cfg.optim.steps as f64)),
            ("full_msg_bytes",
             Json::Num((cfg.model.k * cfg.dataset.dim * 4) as f64)),
        ])),
        ("runs", Json::Arr(rows)),
    ]);
    match dmlps::metrics::write_bench_json("BENCH_ps.json", &out) {
        Ok(path) => println!(
            "\nwrote machine-readable baseline to {}",
            path.display()
        ),
        Err(e) => {
            eprintln!("ERROR: {e}");
            std::process::exit(1);
        }
    }
}
