//! Ablation — native Rust engine vs the AOT-compiled XLA/PJRT engine on
//! the minibatch hot path, across artifact variants.
//!
//! This is the L1/L2-vs-L3 comparison: the XLA path runs the Pallas
//! kernels lowered through HLO (with XLA's fused Eigen matmuls); the
//! native path is our hand-blocked Rust. Skips the XLA rows when
//! artifacts are absent.

use dmlps::dml::{DmlProblem, Engine, MinibatchRef, NativeEngine};
use dmlps::runtime::artifacts_dir;
use dmlps::util::bench::Bench;
use dmlps::util::rng::Pcg32;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let mut b = Bench::new("engine comparison: minibatch step")
        .with_target_time(Duration::from_millis(if quick { 400 } else {
            2000
        }));

    let variants = ["test_small", "mnist", "imnet60k_scaled",
                    "imnet1m_scaled"];
    for variant in variants {
        let Ok(manifest) = dmlps::runtime::Manifest::load(&artifacts_dir())
        else {
            continue;
        };
        let Ok(shape) = manifest.variant(variant) else { continue };
        let (k, d, bs, bd) = (shape.k, shape.d, shape.bs, shape.bd);
        let problem = DmlProblem::new(d, k, 1.0);
        let l0 = problem.init_l(0.1, 0);
        let mut rng = Pcg32::new(2);
        let mut dsb = vec![0.0f32; bs * d];
        let mut ddb = vec![0.0f32; bd * d];
        rng.fill_gaussian(&mut dsb, 0.0, 1.0);
        rng.fill_gaussian(&mut ddb, 0.0, 1.0);
        let flops = problem.step_flops(bs, bd);

        // native
        let mut eng = NativeEngine::new();
        let mut l = l0.clone();
        b.bench_with_work(
            &format!("{variant} native step"),
            Some(flops),
            || {
                let batch = MinibatchRef::new(&dsb, &ddb, bs, bd, d);
                eng.step(&mut l, &batch, 1.0, 1e-6).unwrap();
            },
        );

        // xla (only in builds that carry the PJRT bindings)
        #[cfg(feature = "xla")]
        {
            use dmlps::linalg::Mat;
            use dmlps::runtime::{artifacts_available, XlaEngine};
            if artifacts_available() {
                let mut xe = XlaEngine::load(&artifacts_dir(), variant)?;
                let mut l = l0.clone();
                b.bench_with_work(
                    &format!("{variant} xla step (fused, donated)"),
                    Some(flops),
                    || {
                        let batch =
                            MinibatchRef::new(&dsb, &ddb, bs, bd, d);
                        xe.step(&mut l, &batch, 1.0, 1e-6).unwrap();
                    },
                );
                // loss_grad path (what PS workers call)
                let mut g = Mat::zeros(k, d);
                let mut xe2 = XlaEngine::load(&artifacts_dir(), variant)?;
                b.bench_with_work(
                    &format!("{variant} xla loss_grad"),
                    Some(flops),
                    || {
                        let batch =
                            MinibatchRef::new(&dsb, &ddb, bs, bd, d);
                        xe2.loss_grad(&l0, &batch, 1.0, &mut g).unwrap();
                    },
                );
            }
        }
    }
    b.report();
    println!(
        "\n(throughput = FLOP rate; the xla rows include literal \
         marshalling host↔device, which is the price of the AOT runtime \
         boundary — see EXPERIMENTS.md §Perf)"
    );
    Ok(())
}
