//! Ablation: materialized vs streaming pair pipeline (`pairs.mode`).
//!
//! Three measurements, written to **`BENCH_pairs.json`** (override the
//! path with `DMLPS_BENCH_OUT`):
//!
//! 1. **MNIST shape** — startup time (sample + clone-and-shuffle
//!    partition vs class-index build), resident pair bytes, and raw
//!    pair-draw throughput for both pipelines.
//! 2. **Paper-extrapolated shape** — 1M points / 200M pairs (§5): the
//!    materialized pair-storage term is computed arithmetically
//!    (materializing it is exactly what the streaming pipeline makes
//!    unnecessary), streaming startup + draw rate are measured for
//!    real on a 1M-point label set.
//! 3. **End-to-end** — the same step budget trained in both modes:
//!    streaming must complete it with zero resident pair bytes.

use std::sync::Arc;
use std::time::Instant;

use dmlps::config::{FeatureKind, PairMode, Preset};
use dmlps::data::{
    partition_pairs, ClassIndex, Dataset, ExperimentData,
    ImplicitPairSampler, MaterializedStream, PairSet, PairStream,
    SyntheticSpec,
};
use dmlps::ps::RunOptions;
use dmlps::session::Session;
use dmlps::util::json::Json;
use dmlps::util::rng::Pcg32;

const PAIR_BYTES: usize = 8; // two u32 indices
const PAPER_PAIRS: f64 = 200e6; // §5: 100M similar + 100M dissimilar

/// Draw `n` pairs alternating streams; fold a checksum so the draws
/// cannot be optimized away. Returns pairs/sec.
fn draw_rate(stream: &mut dyn PairStream, n: usize) -> (f64, u64) {
    let t0 = Instant::now();
    let mut checksum = 0u64;
    for _ in 0..n / 2 {
        checksum = checksum.wrapping_add(stream.next_similar().i as u64);
        checksum =
            checksum.wrapping_add(stream.next_dissimilar().j as u64);
    }
    (n as f64 / t0.elapsed().as_secs_f64().max(1e-9), checksum)
}

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let workers = 4usize;
    let seed = 42u64;

    // ---------------- stage 1: MNIST shape ----------------
    let mut cfg = Preset::Mnist.config();
    cfg.dataset.n_train = 6_000; // data-gen cost out of the startup timer
    let n_pairs = if quick { 20_000 } else { 100_000 };
    let spec = SyntheticSpec::from_config(&cfg.dataset);
    let mut rng = Pcg32::with_stream(seed, 0xDA7A);
    let train = Arc::new(spec.generate_with(&mut rng, cfg.dataset.n_train));
    println!(
        "ablation_pairstream: MNIST shape, {} train points, \
         {n_pairs}+{n_pairs} pairs, {workers} workers",
        cfg.dataset.n_train
    );

    let t0 = Instant::now();
    let pairs = PairSet::sample(
        &train,
        n_pairs,
        n_pairs,
        &mut Pcg32::with_stream(seed, 0x9999),
    );
    let shards = partition_pairs(&pairs, workers, seed).unwrap();
    let mat_startup_s = t0.elapsed().as_secs_f64();
    let mat_bytes = pairs.len() * PAIR_BYTES
        + shards
            .iter()
            .map(|s| s.pairs.len() * PAIR_BYTES)
            .sum::<usize>();

    let t0 = Instant::now();
    let index = Arc::new(ClassIndex::build(&train, 0.0).unwrap());
    let samplers: Vec<ImplicitPairSampler> = (0..workers)
        .map(|w| {
            ImplicitPairSampler::with_index(
                train.clone(),
                index.clone(),
                seed,
                w,
                workers,
                0.0,
            )
        })
        .collect();
    let str_startup_s = t0.elapsed().as_secs_f64();
    let str_pair_bytes: usize =
        samplers.iter().map(|s| s.pair_bytes()).sum();
    let str_index_bytes = index.index_bytes(); // shared, counted once
    drop(samplers);

    let draws = if quick { 200_000 } else { 2_000_000 };
    let mut mat_stream =
        MaterializedStream::new(pairs.clone(), Pcg32::new(7));
    let (mat_rate, ck1) = draw_rate(&mut mat_stream, draws);
    let mut str_stream =
        ImplicitPairSampler::with_index(train.clone(), index, seed, 0, 1, 0.0);
    let (str_rate, ck2) = draw_rate(&mut str_stream, draws);

    println!(
        "\n| pipeline | startup s | pair bytes | index bytes | pairs/s |"
    );
    println!("|---|---|---|---|---|");
    println!(
        "| materialized | {mat_startup_s:.4} | {mat_bytes} | 0 | \
         {mat_rate:.0} |"
    );
    println!(
        "| streaming | {str_startup_s:.4} | {str_pair_bytes} | \
         {str_index_bytes} | {str_rate:.0} |  (checksums {ck1:x}/{ck2:x})"
    );

    // ---------------- stage 2: paper-extrapolated shape ----------------
    let n_points = if quick { 100_000 } else { 1_000_000 };
    let paper_spec = SyntheticSpec {
        kind: FeatureKind::Gaussian,
        dim: 8, // label geometry only; pair draws never touch features
        n_classes: 1000,
        separation: 3.0,
        signal_fraction: 0.5,
        noise_amp: 1.0,
        outlier_prob: 0.0,
        outlier_amp: 1.0,
        llc_active: 4,
        class_seed: 0xC1A55,
    };
    let big: Arc<Dataset> = Arc::new(paper_spec.generate_with(
        &mut Pcg32::with_stream(seed, 0xB16),
        n_points,
    ));
    let t0 = Instant::now();
    let mut big_sampler =
        ImplicitPairSampler::new(big.clone(), seed, 0, 1, 0.0, 0.0)
            .unwrap();
    let big_startup_s = t0.elapsed().as_secs_f64();
    let big_draws = if quick { 100_000 } else { 1_000_000 };
    let (big_rate, _) = draw_rate(&mut big_sampler, big_draws);
    let paper_mat_bytes = PAPER_PAIRS * PAIR_BYTES as f64;
    println!(
        "\npaper scale ({n_points} points, 200M pairs): materialized \
         needs {:.2} GB of pair storage (plus a transient clone-and-\
         shuffle copy); streaming holds {} pair bytes + a {:.2} MB \
         shared class index, built in {big_startup_s:.4}s, draws \
         {big_rate:.0} pairs/s",
        paper_mat_bytes / 1e9,
        big_sampler.pair_bytes(),
        big_sampler.index_bytes() as f64 / 1e6,
    );

    // ---------------- stage 3: end-to-end, same step budget ------------
    let mut tcfg = Preset::Tiny.config();
    tcfg.optim.steps = if quick { 30 } else { 120 };
    tcfg.cluster.workers = 2;
    tcfg.artifact_variant = None;
    let opts = RunOptions {
        probe_every: u64::MAX / 2,
        probe_pairs: (50, 50),
        ..Default::default()
    };
    let mut train_rows: Vec<Json> = Vec::new();
    println!(
        "\n| mode | applied | wall s | resident pair bytes | final f |"
    );
    println!("|---|---|---|---|---|");
    for mode in [PairMode::Materialized, PairMode::Streaming] {
        let mut c = tcfg.clone();
        c.cluster.pairs.mode = mode;
        let data = Arc::new(
            ExperimentData::generate_for(&c.dataset, mode, c.seed));
        let r = Session::from_config(c)
            .engine("native")
            .data(data)
            .run_options(opts.clone())
            .train_distributed()
            .expect("pairstream training run");
        let resident: usize =
            r.worker_stats.iter().map(|w| w.pair_bytes).sum();
        let fobj = r.curve.final_objective().unwrap_or(f64::NAN);
        println!(
            "| {} | {} | {:.2} | {resident} | {fobj:.4} |",
            mode.name(),
            r.applied_updates,
            r.wall_s
        );
        train_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.name().into())),
            ("applied_updates", Json::Num(r.applied_updates as f64)),
            ("wall_s", Json::Num(r.wall_s)),
            ("resident_pair_bytes", Json::Num(resident as f64)),
            ("pairs_drawn",
             Json::Num(r.worker_stats.iter()
                 .map(|w| w.pairs_drawn as f64).sum::<f64>())),
            ("final_objective", Json::Num(fobj)),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("ablation_pairstream".into())),
        ("quick", Json::Bool(quick)),
        ("mnist_shape", Json::obj(vec![
            ("n_train", Json::Num(cfg.dataset.n_train as f64)),
            ("n_pairs", Json::Num((2 * n_pairs) as f64)),
            ("workers", Json::Num(workers as f64)),
            ("materialized", Json::obj(vec![
                ("startup_s", Json::Num(mat_startup_s)),
                ("resident_pair_bytes", Json::Num(mat_bytes as f64)),
                ("pairs_per_sec", Json::Num(mat_rate)),
            ])),
            ("streaming", Json::obj(vec![
                ("startup_s", Json::Num(str_startup_s)),
                ("resident_pair_bytes",
                 Json::Num(str_pair_bytes as f64)),
                ("shared_index_bytes",
                 Json::Num(str_index_bytes as f64)),
                ("pairs_per_sec", Json::Num(str_rate)),
            ])),
        ])),
        ("paper_shape", Json::obj(vec![
            ("n_points", Json::Num(n_points as f64)),
            ("n_pairs", Json::Num(PAPER_PAIRS)),
            ("materialized_pair_bytes", Json::Num(paper_mat_bytes)),
            ("streaming_pair_bytes", Json::Num(0.0)),
            ("streaming_index_bytes",
             Json::Num(big_sampler.index_bytes() as f64)),
            ("streaming_startup_s", Json::Num(big_startup_s)),
            ("streaming_pairs_per_sec", Json::Num(big_rate)),
        ])),
        ("train", Json::Arr(train_rows)),
    ]);
    match dmlps::metrics::write_bench_json("BENCH_pairs.json", &out) {
        Ok(path) => println!(
            "\nwrote machine-readable baseline to {}",
            path.display()
        ),
        Err(e) => {
            eprintln!("ERROR: {e}");
            std::process::exit(1);
        }
    }
}
