//! Fig 3 — speedup factors vs number of CPU cores.
//!
//! Protocol exactly as §5.3: "for each machine setting we record the
//! running time that the objective value is decreased to p, where p is
//! the objective value achieved by one single machine at the end of
//! training. The speedup factor of n machines is t_1/t_n."
//!
//! Two sections:
//! * numeric mode — real async-SGD numerics at scaled shapes, paper-true
//!   clock (same machinery as fig2);
//! * cost-only mode at exact paper-true shapes (220M-parameter
//!   ImageNet-63K messages included) via the NullWorkload, reproducing
//!   the paper's headline "3.6×/3.8× at 4 machines (256 cores)" shape.

use std::sync::Arc;

use dmlps::session::{calibrate_for, sim_scaled, Session, SimKnobs};

/// Era calibration: the paper's 2014 testbed retires the minibatch
/// gradient ~10x slower than this box's single core (anchor: the paper
/// reports ~0.5 h single-thread MNIST training in section 5.4; ours measures
/// ~2-3 min at the identical shape). The simulated clock charges
/// paper-era cost so compute/communication ratios match the paper's.
const ERA_SLOWDOWN: f64 = 10.0;
use dmlps::config::{Preset, PAPER_SHAPES};
use dmlps::data::ExperimentData;
use dmlps::dml::LrSchedule;
use dmlps::metrics::speedup_table;
use dmlps::simcluster::{NetworkModel, NullWorkload, SimConfig, Simulator};

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let updates: u64 = if quick { 200 } else { 600 };

    println!("# Fig 3: speedup vs cores (numeric mode)\n");
    let sweeps: [(&str, Preset, usize, &[usize]); 3] = [
        ("Fig 3(a) MNIST", Preset::Mnist, 16, &[16, 32, 64, 128, 256]),
        ("Fig 3(b) ImageNet-63K", Preset::Imnet60kScaled, 64,
         &[64, 128, 256]),
        ("Fig 3(c) ImageNet-1M", Preset::Imnet1mScaled, 64,
         &[64, 128, 256]),
    ];
    for (title, preset, cpm, cores_list) in sweeps {
        let scaled = sim_scaled(preset);
        let cfg = &scaled.cfg;
        let data =
            Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
        let grad_paper = calibrate_for(cfg) * scaled.flop_ratio * ERA_SLOWDOWN;
        // baseline run fixes the target objective p (§5.3 protocol)
        let mut curves = Vec::new();
        for &cores in cores_list {
            let machines = (cores / cpm).max(1);
            let r = Session::from_config(cfg.clone())
                .data(data.clone())
                .topology(machines, cpm.min(cores))
                .sim_knobs(SimKnobs {
                    grad_seconds: grad_paper,
                    bytes_per_msg: Some(scaled.paper_bytes),
                    total_updates: updates,
                    ..SimKnobs::default()
                })
                .simulate()
                .expect("simulated run");
            curves.push((cores, r.curve));
        }
        let target = curves[0].1.final_objective().unwrap();
        let meas: Vec<(usize, f64)> = curves
            .iter()
            .filter_map(|(cores, c)| {
                c.time_to_reach(target).map(|t| (*cores, t))
            })
            .collect();
        println!("\n## {title} (target f ≤ {target:.4})\n");
        println!("| cores | time-to-target (sim-s) | speedup | linear |");
        println!("|---|---|---|---|");
        for row in speedup_table(meas) {
            println!(
                "| {} | {:.1} | {:.2}x | {:.2}x |",
                row.cores, row.time_to_target_s, row.speedup, row.linear
            );
        }
    }

    println!("\n# cost-only mode at exact paper-true shapes\n");
    println!(
        "(throughput speedup to {updates} applied updates; gradients are \
         inert, message sizes and compute times are paper-true)\n"
    );
    // calibrate once on the real mnist shape, extrapolate by FLOPs
    let mnist_cfg = Preset::Mnist.config();
    let mnist_grad = calibrate_for(&mnist_cfg);
    let mnist_flops = PAPER_SHAPES[0].step_flops();
    for shape in &PAPER_SHAPES {
        let grad = mnist_grad * shape.step_flops() / mnist_flops * ERA_SLOWDOWN;
        let cpm = if shape.name == "MNIST" { 16 } else { 64 };
        println!(
            "## {} (d={}, k={}, {:.0} MB msgs, {:.2}s/grad/core)\n",
            shape.name, shape.d, shape.k,
            shape.n_params() as f64 * 4.0 / 1e6, grad
        );
        let mut meas = Vec::new();
        for machines in [1usize, 2, 4] {
            let cfg = SimConfig {
                machines,
                cores_per_machine: cpm,
                grad_seconds: grad,
                apply_seconds: shape.n_params() as f64 * 8.0 / 4.0e9,
                bytes_per_msg: shape.n_params() as f64 * 4.0,
                network: NetworkModel::ten_gbe(),
                jitter: 0.05,
                total_updates: updates,
                probe_every: updates,
                broadcast_every: 1,
                lr: LrSchedule::constant(0.01),
                seed: 7,
                disruption: None,
            };
            let mut w = NullWorkload;
            let r = Simulator::new(cfg, &mut w).run();
            meas.push((machines * cpm, r.sim_seconds));
        }
        println!("| cores | machines | sim time (s) | speedup | linear |");
        println!("|---|---|---|---|---|");
        for row in speedup_table(meas) {
            println!(
                "| {} | {} | {:.1} | {:.2}x | {:.2}x |",
                row.cores, row.cores / cpm, row.time_to_target_s,
                row.speedup, row.linear
            );
        }
        println!();
    }
    println!(
        "paper reference: 3.6x (ImNet-60K) / 3.8x (ImNet-1M) at 4 \
         machines — compare the 4-machine rows above."
    );
}
