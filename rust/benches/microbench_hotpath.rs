//! Microbenchmarks of the L3 hot-path components: the packed GEMM
//! kernels behind the native engine, the full sharded `loss_grad`, the
//! pair-distance and kNN scan kernels, message-queue throughput, and
//! parameter-copy cost — the quantities the §Perf optimization loop
//! tracks.
//!
//! The kernel-bound sections sweep **backend × threads**: every
//! compiled backend (the bit-exact scalar reference, plus the AVX2+FMA
//! path when `--features simd` is on and the CPU supports it) is forced
//! in turn via `linalg::simd::force_backend`, so `BENCH_hotpath.json`
//! records scalar and SIMD GFLOP/s (and scan GB/s) side by side plus
//! the dispatch decision `auto` would have made.
//!
//! Silent-garbage guard: every measured kernel's output is checked for
//! NaN/Inf after its timing loop; if any check fails the bench prints
//! the offending kernels and exits nonzero **without** writing
//! `BENCH_hotpath.json` — a corrupted baseline is worse than none.
//!
//! Besides the human-readable tables, this writes a machine-readable
//! `BENCH_hotpath.json` (override the path with `DMLPS_BENCH_OUT`).

use dmlps::dml::{DmlProblem, Engine, MinibatchRef, NativeEngine};
use dmlps::linalg::simd::{self, KernelBackend};
use dmlps::linalg::{self, Mat};
use dmlps::util::bench::Bench;
use dmlps::util::json::Json;
use dmlps::util::pool;
use dmlps::util::rng::Pcg32;
use std::time::Duration;

/// Record `name` as non-finite if any value in `data` is NaN/Inf.
fn check_finite(name: &str, data: &[f32], bad: &mut Vec<String>) {
    if data.iter().any(|v| !v.is_finite()) {
        bad.push(name.to_string());
    }
}

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let target = Duration::from_millis(if quick { 300 } else { 1500 });
    let mut rng = Pcg32::new(3);
    let mut groups: Vec<Json> = Vec::new();
    let mut bad: Vec<String> = Vec::new();

    // MNIST shapes (paper Table 1 row 1): d=780, k=600, minibatch 500+500
    let d = 780;
    let k = 600;
    let bsz = 500;
    let gallery_rows = if quick { 1000 } else { 4000 };

    let auto_report = simd::report();
    println!("kernel dispatch (auto): {auto_report}");
    let mut backends = vec![KernelBackend::Scalar];
    if auto_report.compiled_simd && auto_report.cpu_supported {
        backends.push(KernelBackend::Simd);
    }

    let mut l = Mat::zeros(k, d);
    rng.fill_gaussian(&mut l.data, 0.0, 0.1);
    let mut diffs = Mat::zeros(bsz, d);
    rng.fill_gaussian(&mut diffs.data, 0.0, 1.0);
    let va: Vec<f32> = (0..d).map(|i| i as f32 * 0.01).collect();
    let vb: Vec<f32> = (0..d).map(|i| 1.0 - i as f32 * 0.001).collect();

    // projected-space gallery + query for the kNN scan (k-dim rows: the
    // serving layout MetricModel::knn_projected scans)
    let mut gallery = Mat::zeros(gallery_rows, k);
    rng.fill_gaussian(&mut gallery.data, 0.0, 1.0);
    let mut query = vec![0.0f32; k];
    rng.fill_gaussian(&mut query, 0.0, 1.0);

    let problem = DmlProblem::new(d, k, 1.0);
    let mut dsb = vec![0.0f32; bsz * d];
    let mut ddb = vec![0.0f32; bsz * d];
    rng.fill_gaussian(&mut dsb, 0.0, 1.0);
    rng.fill_gaussian(&mut ddb, 0.0, 1.0);
    let step_flops = problem.step_flops(bsz, bsz);
    let z_flops = 2.0 * bsz as f64 * k as f64 * d as f64;

    // the acceptance-tracked sweep: 1 vs 4 threads (plus the machine
    // default when it differs)
    let mut sweep: Vec<usize> = vec![1, 2, 4];
    let auto = pool::default_threads();
    if !sweep.contains(&auto) {
        sweep.push(auto);
    }

    // per-backend metric maps for the machine-readable baseline
    let mut gflops_by_backend: Vec<(String, Json)> = Vec::new();
    let mut knn_gbps_by_backend: Vec<(String, Json)> = Vec::new();
    let mut pair_gflops_by_backend: Vec<(String, Json)> = Vec::new();
    let mut auto_gflops_by_threads: Vec<(String, Json)> = Vec::new();

    for &be in &backends {
        simd::force_backend(Some(be));
        let active = simd::report();
        assert_eq!(
            active.backend, be,
            "forced backend did not take effect"
        );

        // ---- dot / matmul kernels at mnist shapes ----
        let mut b = Bench::new(&format!(
            "linalg kernels (mnist shapes, {be} backend)"
        ))
        .with_target_time(target);
        b.bench_with_work("dot(780)", Some(2.0 * d as f64), || {
            std::hint::black_box(linalg::simd::dot(&va, &vb));
        });
        check_finite(
            &format!("dot[{be}]"),
            &[linalg::simd::dot(&va, &vb)],
            &mut bad,
        );
        b.bench_with_work(
            &format!(
                "project Z = D·Lᵀ (500×780 · 780×600, {} threads)",
                pool::global().threads()
            ),
            Some(z_flops),
            || {
                std::hint::black_box(diffs.matmul_bt(&l));
            },
        );
        let z = diffs.matmul_bt(&l);
        check_finite(&format!("project[{be}]"), &z.data, &mut bad);
        let mut g = Mat::zeros(k, d);
        b.bench_with_work(
            &format!(
                "outer G = Zᵀ·D (600×500 · 500×780, {} threads)",
                pool::global().threads()
            ),
            Some(z_flops),
            || {
                linalg::matmul_at_into(&z, &diffs, &mut g, 0.0);
            },
        );
        check_finite(&format!("outer[{be}]"), &g.data, &mut bad);
        b.report();
        groups.push(b.to_json());

        // ---- full engine step: sharded loss_grad across threads ----
        let mut b = Bench::new(&format!(
            "native engine, mnist minibatch ({be} backend)"
        ))
        .with_target_time(target);
        let mut gflops_by_threads: Vec<(String, Json)> = Vec::new();
        for &threads in &sweep {
            let mut eng = NativeEngine::with_threads(threads);
            let mut g = Mat::zeros(k, d);
            let m = b.bench_with_work(
                &format!("loss_grad (4 GEMMs + hinge, {threads} threads)"),
                Some(step_flops),
                || {
                    let batch =
                        MinibatchRef::new(&dsb, &ddb, bsz, bsz, d);
                    eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
                },
            );
            gflops_by_threads.push((
                threads.to_string(),
                Json::Num(m.throughput().unwrap_or(0.0) / 1e9),
            ));
            check_finite(
                &format!("loss_grad[{be},{threads}t]"),
                &g.data,
                &mut bad,
            );
        }
        if be == auto_report.backend {
            auto_gflops_by_threads = gflops_by_threads.clone();
        }
        let mut eng = NativeEngine::new();
        let mut l2 = l.clone();
        b.bench_with_work(
            &format!("step (loss_grad + axpy, {} threads)", eng.threads()),
            Some(step_flops),
            || {
                let batch = MinibatchRef::new(&dsb, &ddb, bsz, bsz, d);
                eng.step(&mut l2, &batch, 1.0, 1e-7).unwrap();
            },
        );
        check_finite(&format!("step[{be}]"), &l2.data, &mut bad);
        b.report();
        groups.push(b.to_json());
        gflops_by_backend.push((
            be.name().to_string(),
            Json::Obj(gflops_by_threads.into_iter().collect()),
        ));

        // ---- scan kernels: pair-distance + blocked kNN ----
        let mut b = Bench::new(&format!(
            "scan kernels ({be} backend)"
        ))
        .with_target_time(target);
        let pair_flops = 2.0 * bsz as f64 * k as f64 * d as f64;
        let mut eng = NativeEngine::new();
        let m = b.bench_with_work(
            &format!("pair_dist ({bsz} pairs × k={k} dots, d={d})"),
            Some(pair_flops),
            || {
                std::hint::black_box(
                    eng.pair_dist(&l, &diffs).unwrap(),
                );
            },
        );
        pair_gflops_by_backend.push((
            be.name().to_string(),
            Json::Num(m.throughput().unwrap_or(0.0) / 1e9),
        ));
        check_finite(
            &format!("pair_dist[{be}]"),
            &eng.pair_dist(&l, &diffs).unwrap(),
            &mut bad,
        );
        let scan_bytes = (gallery_rows * k * 4) as f64;
        let m = b.bench_with_work(
            &format!(
                "nearest_k scan ({gallery_rows}×{k} gallery, k=10)"
            ),
            Some(scan_bytes),
            || {
                std::hint::black_box(dmlps::eval::nearest_k(
                    &gallery, &query, 10,
                ));
            },
        );
        knn_gbps_by_backend.push((
            be.name().to_string(),
            Json::Num(m.throughput().unwrap_or(0.0) / 1e9),
        ));
        let knn_dists: Vec<f32> = dmlps::eval::nearest_k(
            &gallery, &query, 10,
        )
        .into_iter()
        .map(|(dist, _)| dist)
        .collect();
        check_finite(&format!("nearest_k[{be}]"), &knn_dists, &mut bad);
        b.report();
        groups.push(b.to_json());
    }
    simd::force_backend(None);

    // ---- PS plumbing: queue throughput & parameter copies ----
    let mut b = Bench::new("parameter-server plumbing")
        .with_target_time(target);
    let payload: Vec<f32> = vec![0.0; k * d];
    b.bench_with_work(
        "mpsc send+recv of k×d gradient",
        Some((k * d * 4) as f64),
        || {
            let (tx, rx) = std::sync::mpsc::channel();
            tx.send(payload.clone()).unwrap();
            std::hint::black_box(rx.recv().unwrap());
        },
    );
    let mut dst = vec![0.0f32; k * d];
    b.bench_with_work(
        "copy_from_slice k×d params (1.87 MB)",
        Some((k * d * 4) as f64),
        || {
            dst.copy_from_slice(&payload);
            std::hint::black_box(&dst);
        },
    );
    let src = Mat::zeros(k, d);
    b.bench_with_work("axpy k×d (server apply)",
                      Some((k * d * 2) as f64), || {
        let mut t = src.clone();
        t.axpy_inplace(-0.01, &l);
        std::hint::black_box(&t);
    });
    b.report();
    groups.push(b.to_json());

    // ---- minibatch materialization (diff_into path) ----
    let mut b = Bench::new("minibatch materialization")
        .with_target_time(target);
    let spec = dmlps::data::SyntheticSpec::tiny();
    let ds = spec.generate(0);
    let mut prng = Pcg32::new(9);
    let pairs = dmlps::data::PairSet::sample(&ds, 5_000, 5_000, &mut prng);
    let mut it = dmlps::data::MinibatchIter::new(
        &ds, &pairs, 128, 128, Pcg32::new(10),
    );
    b.bench_with_work(
        "fill 128+128 pair diffs (d=16)",
        Some((256 * 16 * 4) as f64),
        || it.next_batch(),
    );
    b.report();
    groups.push(b.to_json());

    // ---- silent-garbage guard: refuse to write a poisoned baseline ----
    if !bad.is_empty() {
        eprintln!(
            "ERROR: non-finite kernel output in: {} — refusing to \
             write BENCH_hotpath.json",
            bad.join(", ")
        );
        std::process::exit(1);
    }

    // ---- machine-readable perf baseline ----
    let out = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("quick", Json::Bool(quick)),
        ("default_threads", Json::Num(auto as f64)),
        // the backend `auto` dispatch resolves to on this machine/build
        ("backend", Json::Str(auto_report.backend.name().into())),
        ("kernel_dispatch", Json::obj(vec![
            ("backend", Json::Str(auto_report.backend.name().into())),
            ("lanes", Json::Num(auto_report.lanes as f64)),
            ("compiled_simd", Json::Bool(auto_report.compiled_simd)),
            ("cpu_supported", Json::Bool(auto_report.cpu_supported)),
            ("decision",
             Json::Str(auto_report.decision.name().into())),
        ])),
        ("backends_measured", Json::Arr(
            backends.iter()
                .map(|b| Json::Str(b.name().into()))
                .collect(),
        )),
        ("shapes", Json::obj(vec![
            ("k", Json::Num(k as f64)),
            ("d", Json::Num(d as f64)),
            ("batch_sim", Json::Num(bsz as f64)),
            ("batch_dis", Json::Num(bsz as f64)),
            ("knn_gallery_rows", Json::Num(gallery_rows as f64)),
        ])),
        // auto-backend numbers under the legacy key (perf continuity),
        // full backend × threads matrix alongside
        ("loss_grad_gflops_by_threads",
         Json::Obj(auto_gflops_by_threads.into_iter().collect())),
        ("loss_grad_gflops_by_backend",
         Json::Obj(gflops_by_backend.into_iter().collect())),
        ("pair_dist_gflops_by_backend",
         Json::Obj(pair_gflops_by_backend.into_iter().collect())),
        ("knn_scan_gbps_by_backend",
         Json::Obj(knn_gbps_by_backend.into_iter().collect())),
        ("groups", Json::Arr(groups)),
    ]);
    match dmlps::metrics::write_bench_json("BENCH_hotpath.json", &out) {
        Ok(path) => println!(
            "\nwrote machine-readable baseline to {}",
            path.display()
        ),
        Err(e) => {
            eprintln!("ERROR: {e}");
            std::process::exit(1);
        }
    }
}
