//! Microbenchmarks of the L3 hot-path components: the packed GEMM
//! kernels behind the native engine, the full sharded `loss_grad` across
//! thread counts, message-queue throughput, and parameter-copy cost —
//! the quantities the §Perf optimization loop tracks.
//!
//! Besides the human-readable tables, this bench writes a
//! machine-readable `BENCH_hotpath.json` (override the path with
//! `DMLPS_BENCH_OUT`) so future PRs have a standing perf baseline:
//! GFLOP/s per kernel, per thread count, at the paper's MNIST shapes.

use dmlps::dml::{DmlProblem, Engine, MinibatchRef, NativeEngine};
use dmlps::linalg::{self, Mat};
use dmlps::util::bench::Bench;
use dmlps::util::json::Json;
use dmlps::util::pool;
use dmlps::util::rng::Pcg32;
use std::time::Duration;

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let target = Duration::from_millis(if quick { 300 } else { 1500 });
    let mut rng = Pcg32::new(3);
    let mut groups: Vec<Json> = Vec::new();

    // MNIST shapes (paper Table 1 row 1): d=780, k=600, minibatch 500+500
    let d = 780;
    let k = 600;
    let bsz = 500;

    // ---- dot / matmul kernels at mnist shapes ----
    let mut b = Bench::new("linalg kernels (mnist shapes)")
        .with_target_time(target);
    let mut l = Mat::zeros(k, d);
    rng.fill_gaussian(&mut l.data, 0.0, 0.1);
    let mut diffs = Mat::zeros(bsz, d);
    rng.fill_gaussian(&mut diffs.data, 0.0, 1.0);

    let va: Vec<f32> = (0..d).map(|i| i as f32 * 0.01).collect();
    let vb: Vec<f32> = (0..d).map(|i| 1.0 - i as f32 * 0.001).collect();
    b.bench_with_work("dot(780)", Some(2.0 * d as f64), || {
        std::hint::black_box(linalg::dot(&va, &vb));
    });

    let z_flops = 2.0 * bsz as f64 * k as f64 * d as f64;
    b.bench_with_work(
        &format!(
            "project Z = D·Lᵀ (500×780 · 780×600, {} threads)",
            pool::global().threads()
        ),
        Some(z_flops),
        || {
            std::hint::black_box(diffs.matmul_bt(&l));
        },
    );

    let z = diffs.matmul_bt(&l);
    let mut g = Mat::zeros(k, d);
    b.bench_with_work(
        &format!(
            "outer G = Zᵀ·D (600×500 · 500×780, {} threads)",
            pool::global().threads()
        ),
        Some(z_flops),
        || {
            linalg::matmul_at_into(&z, &diffs, &mut g, 0.0);
        },
    );
    b.report();
    groups.push(b.to_json());

    // ---- full engine step: sharded loss_grad across thread counts ----
    let mut b = Bench::new("native engine, mnist minibatch")
        .with_target_time(target);
    let problem = DmlProblem::new(d, k, 1.0);
    let mut dsb = vec![0.0f32; bsz * d];
    let mut ddb = vec![0.0f32; bsz * d];
    rng.fill_gaussian(&mut dsb, 0.0, 1.0);
    rng.fill_gaussian(&mut ddb, 0.0, 1.0);
    let step_flops = problem.step_flops(bsz, bsz);

    // the acceptance-tracked sweep: 1 vs 4 threads (plus the machine
    // default when it differs)
    let mut sweep: Vec<usize> = vec![1, 2, 4];
    let auto = pool::default_threads();
    if !sweep.contains(&auto) {
        sweep.push(auto);
    }
    let mut gflops_by_threads: Vec<(String, Json)> = Vec::new();
    for &threads in &sweep {
        let mut eng = NativeEngine::with_threads(threads);
        let mut g = Mat::zeros(k, d);
        let m = b.bench_with_work(
            &format!("loss_grad (4 GEMMs + hinge, {threads} threads)"),
            Some(step_flops),
            || {
                let batch = MinibatchRef::new(&dsb, &ddb, bsz, bsz, d);
                eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
            },
        );
        gflops_by_threads.push((
            threads.to_string(),
            Json::Num(m.throughput().unwrap_or(0.0) / 1e9),
        ));
    }

    let mut eng = NativeEngine::new();
    let mut l2 = l.clone();
    b.bench_with_work(
        &format!(
            "step (loss_grad + axpy, {} threads)",
            eng.threads()
        ),
        Some(step_flops),
        || {
            let batch = MinibatchRef::new(&dsb, &ddb, bsz, bsz, d);
            eng.step(&mut l2, &batch, 1.0, 1e-7).unwrap();
        },
    );
    b.report();
    groups.push(b.to_json());

    // ---- PS plumbing: queue throughput & parameter copies ----
    let mut b = Bench::new("parameter-server plumbing")
        .with_target_time(target);
    let payload: Vec<f32> = vec![0.0; k * d];
    b.bench_with_work(
        "mpsc send+recv of k×d gradient",
        Some((k * d * 4) as f64),
        || {
            let (tx, rx) = std::sync::mpsc::channel();
            tx.send(payload.clone()).unwrap();
            std::hint::black_box(rx.recv().unwrap());
        },
    );
    let mut dst = vec![0.0f32; k * d];
    b.bench_with_work(
        "copy_from_slice k×d params (1.87 MB)",
        Some((k * d * 4) as f64),
        || {
            dst.copy_from_slice(&payload);
            std::hint::black_box(&dst);
        },
    );
    let src = Mat::zeros(k, d);
    b.bench_with_work("axpy k×d (server apply)",
                      Some((k * d * 2) as f64), || {
        let mut t = src.clone();
        t.axpy_inplace(-0.01, &l);
        std::hint::black_box(&t);
    });
    b.report();
    groups.push(b.to_json());

    // ---- minibatch materialization (diff_into path) ----
    let mut b = Bench::new("minibatch materialization")
        .with_target_time(target);
    let spec = dmlps::data::SyntheticSpec::tiny();
    let ds = spec.generate(0);
    let mut prng = Pcg32::new(9);
    let pairs = dmlps::data::PairSet::sample(&ds, 5_000, 5_000, &mut prng);
    let mut it = dmlps::data::MinibatchIter::new(
        &ds, &pairs, 128, 128, Pcg32::new(10),
    );
    b.bench_with_work(
        "fill 128+128 pair diffs (d=16)",
        Some((256 * 16 * 4) as f64),
        || it.next_batch(),
    );
    b.report();
    groups.push(b.to_json());

    // ---- machine-readable perf baseline ----
    let out = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("quick", Json::Bool(quick)),
        ("default_threads", Json::Num(auto as f64)),
        ("shapes", Json::obj(vec![
            ("k", Json::Num(k as f64)),
            ("d", Json::Num(d as f64)),
            ("batch_sim", Json::Num(bsz as f64)),
            ("batch_dis", Json::Num(bsz as f64)),
        ])),
        ("loss_grad_gflops_by_threads",
         Json::Obj(gflops_by_threads.into_iter().collect())),
        ("groups", Json::Arr(groups)),
    ]);
    let path = std::env::var("DMLPS_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".into());
    std::fs::write(&path, out.to_string_pretty())
        .expect("write bench json");
    println!("\nwrote machine-readable baseline to {path}");
}
