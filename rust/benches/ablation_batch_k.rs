//! Ablation — minibatch size and factor rank k.
//!
//! (a) step latency & achieved FLOP rate vs (batch, k) at the MNIST
//!     input dimension (native engine);
//! (b) convergence per update vs batch size at fixed compute budget
//!     (why the paper uses 1000-pair minibatches instead of ITML-style
//!     single-pair updates).

use dmlps::config::{FeatureKind, Preset};
use dmlps::data::ExperimentData;
use dmlps::dml::{DmlProblem, Engine, MinibatchRef, NativeEngine};
use dmlps::linalg::Mat;
use dmlps::util::bench::{format_throughput, Bench};
use dmlps::util::rng::Pcg32;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();

    // ---------------- (a) step latency sweep ----------------
    println!("# Ablation (a): step latency vs batch and k (d=780)\n");
    let d = 780;
    let mut b = Bench::new("native loss_grad @ d=780")
        .with_target_time(Duration::from_millis(if quick { 300 } else {
            1500
        }));
    for &k in &[100usize, 300, 600] {
        for &batch in &[64usize, 256, 1000] {
            let bs = batch / 2;
            let problem = DmlProblem::new(d, k, 1.0);
            let l = problem.init_l(0.1, 0);
            let mut rng = Pcg32::new(1);
            let mut dsb = vec![0.0f32; bs * d];
            let mut ddb = vec![0.0f32; bs * d];
            rng.fill_gaussian(&mut dsb, 0.0, 1.0);
            rng.fill_gaussian(&mut ddb, 0.0, 1.0);
            let mut g = Mat::zeros(k, d);
            let mut eng = NativeEngine::new();
            let flops = problem.step_flops(bs, bs);
            b.bench_with_work(
                &format!("k={k} batch={batch}"),
                Some(flops),
                || {
                    let batch = MinibatchRef::new(&dsb, &ddb, bs, bs, d);
                    eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
                },
            );
        }
    }
    b.report();
    if let Some(best) = b
        .rows()
        .iter()
        .filter_map(|m| m.throughput())
        .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.max(t))))
    {
        println!("\npeak native rate: {}", format_throughput(best));
    }

    // ---------------- (b) convergence per update ----------------
    println!("\n# Ablation (b): quality at equal pair budget vs batch\n");
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.kind = FeatureKind::Gaussian;
    cfg.dataset.dim = 64;
    cfg.dataset.n_classes = 10;
    cfg.dataset.separation = 2.5;
    cfg.dataset.n_train = 2_000;
    cfg.dataset.n_similar = 5_000;
    cfg.dataset.n_dissimilar = 5_000;
    cfg.model.k = 32;
    cfg.artifact_variant = None;
    let data = std::sync::Arc::new(
        ExperimentData::generate(&cfg.dataset, cfg.seed));
    let pair_budget = if quick { 20_000 } else { 100_000 };
    println!("| batch | steps | final objective | test AP |");
    println!("|---|---|---|---|");
    for &batch in &[2usize, 8, 32, 128] {
        let mut c = cfg.clone();
        c.optim.batch_sim = batch;
        c.optim.batch_dis = batch;
        c.optim.steps = pair_budget / (2 * batch);
        let steps = c.optim.steps;
        let run = dmlps::session::Session::from_config(c)
            .data(data.clone())
            .probe(steps.max(1) as u64, (500, 500))
            .train_sequential()?;
        let mut eng = NativeEngine::new();
        let ap = dmlps::eval::ap_of_l(&mut eng, run.l()?, &data)?;
        println!(
            "| {} | {} | {:.4} | {:.4} |",
            2 * batch,
            steps,
            run.curve.final_objective().unwrap_or(f64::NAN),
            ap
        );
    }
    Ok(())
}
