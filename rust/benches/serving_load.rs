//! Serving load generator: drives the retrieval server over real TCP
//! and reports QPS / p50 / p99 / recall@k into **`BENCH_serving.json`**
//! (override the path with `DMLPS_BENCH_OUT`; `DMLPS_BENCH_QUICK`
//! shrinks everything to a CI smoke run).
//!
//! Two load shapes, because they answer different questions:
//!
//! * **closed loop** — `threads × batch × exact/approx` sweep where
//!   each client thread sends its next batch the moment the previous
//!   answer lands. Measures capacity: QPS at saturation and the
//!   in-service latency distribution.
//! * **open loop** — queries arrive on a fixed schedule regardless of
//!   completions, and latency is measured from the *scheduled* arrival,
//!   so queueing delay is visible (the closed-loop blind spot).
//!
//! recall@k compares the approximate path at the benched
//! [`default_nprobe`] against the exact scan on the same queries — the
//! figure `prop_serve` holds to the ≥ 0.9 floor.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dmlps::config::Preset;
use dmlps::data::SyntheticSpec;
use dmlps::linalg::Mat;
use dmlps::ps::net::{NetAddr, RetryPolicy};
use dmlps::serve::{
    default_nprobe, ScanMode, ServeClient, ServeConfig, ServeEngine,
    ServeLimits, ServeServer,
};
use dmlps::session::MetricModel;
use dmlps::util::json::Json;
use dmlps::util::rng::Pcg32;
use dmlps::util::stats::percentile;

const K: usize = 10;
const NCLUSTERS: usize = 32;

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let n_gallery = if quick { 2_000 } else { 20_000 };
    let kproj = 16usize;

    // gallery + queries from the same synthetic family, so the coarse
    // clusters the quantizer finds are real structure, not noise
    let mut spec = SyntheticSpec::tiny();
    spec.dim = 32;
    spec.n_classes = 16;
    spec.separation = 4.0;
    let mut rng = Pcg32::with_stream(7, 0x5EED);
    let gallery = spec.generate_with(&mut rng, n_gallery);
    let queries = spec.generate_with(&mut rng, 4096).x;

    let mut l = Mat::zeros(kproj, spec.dim);
    Pcg32::new(21).fill_gaussian(&mut l.data, 0.0, 0.3);
    let model = MetricModel::new(l, &Preset::Tiny.config());

    println!(
        "serving_load: gallery {n_gallery}×{}, projection {kproj}, \
         {NCLUSTERS} clusters, k={K}{}",
        spec.dim,
        if quick { " (quick)" } else { "" }
    );
    let t0 = Instant::now();
    let engine = Arc::new(ServeEngine::new(
        model,
        &gallery,
        ServeConfig { nclusters: NCLUSTERS, ..ServeConfig::default() },
    ));
    println!("  epoch built in {:.2}s", t0.elapsed().as_secs_f64());

    let nprobe = default_nprobe(NCLUSTERS);

    // ---- recall@k: approximate path vs exact reference, in-process ----
    let n_recall = if quick { 50 } else { 500 };
    let mut hit = 0usize;
    let mut denom = 0usize;
    for r in 0..n_recall {
        let q = queries.row(r % queries.rows);
        let (_, exact) = engine.query_one(q, K, ScanMode::Exact);
        let (_, approx) = engine.query_one(q, K, ScanMode::Probe(nprobe));
        denom += exact.len();
        for (i, _) in &approx {
            if exact.iter().any(|(j, _)| j == i) {
                hit += 1;
            }
        }
    }
    let recall = hit as f64 / denom.max(1) as f64;
    println!("  recall@{K} at nprobe={nprobe}: {recall:.4}");

    // ---- socket front end ----
    let server = ServeServer::bind(
        &NetAddr::parse("127.0.0.1:0").expect("parse addr"),
        Arc::clone(&engine),
        ServeLimits::default(),
    )
    .expect("bind serve socket");
    let mut handle = server.spawn().expect("spawn server");
    let addr = handle.addr().clone();

    // ---- closed loop: threads × batch × mode ----
    let thread_sweep: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let batch_sweep: &[usize] = &[1, 16];
    let batches_total = if quick { 40 } else { 600 };
    let mut closed = Vec::new();
    println!("  closed loop ({batches_total} batches/config):");
    for &threads in thread_sweep {
        for &batch in batch_sweep {
            for (mode_name, wire_nprobe) in
                [("exact", 0usize), ("approx", nprobe)]
            {
                let per_thread = (batches_total / threads).max(1);
                let started = Instant::now();
                let mut lat_ms: Vec<f64> = Vec::new();
                std::thread::scope(|s| {
                    let mut joins = Vec::new();
                    for t in 0..threads {
                        let addr = &addr;
                        let queries = &queries;
                        joins.push(s.spawn(move || {
                            let (mut client, _) = ServeClient::connect(
                                addr,
                                RetryPolicy::default(),
                            )
                            .expect("connect");
                            let mut lats = Vec::with_capacity(per_thread);
                            let mut x = Mat::zeros(batch, queries.cols);
                            for b in 0..per_thread {
                                for r in 0..batch {
                                    let src = (t * per_thread * batch
                                        + b * batch
                                        + r)
                                        % queries.rows;
                                    x.row_mut(r)
                                        .copy_from_slice(queries.row(src));
                                }
                                let sent = Instant::now();
                                client
                                    .query(&x, K, wire_nprobe, b as u64)
                                    .expect("query");
                                lats.push(
                                    sent.elapsed().as_secs_f64() * 1e3,
                                );
                            }
                            lats
                        }));
                    }
                    for j in joins {
                        lat_ms.extend(j.join().expect("client thread"));
                    }
                });
                let wall = started.elapsed().as_secs_f64();
                let rows = (per_thread * threads * batch) as f64;
                let qps = rows / wall;
                let (p50, p99) =
                    (percentile(&lat_ms, 50.0), percentile(&lat_ms, 99.0));
                println!(
                    "    {threads}t × batch {batch:>2} {mode_name:>6}: \
                     {qps:>9.0} rows/s  p50 {p50:.3} ms  p99 {p99:.3} ms"
                );
                closed.push(Json::obj(vec![
                    ("threads", Json::Num(threads as f64)),
                    ("batch", Json::Num(batch as f64)),
                    ("mode", Json::Str(mode_name.into())),
                    ("qps", Json::Num(qps)),
                    ("p50_ms", Json::Num(p50)),
                    ("p99_ms", Json::Num(p99)),
                ]));
            }
        }
    }

    // ---- open loop: fixed arrival schedule, latency from scheduled
    // arrival (queueing delay included) ----
    let rate = if quick { 200.0 } else { 2000.0 };
    let n_open = if quick { 100 } else { 4000 };
    let (mut client, _) =
        ServeClient::connect(&addr, RetryPolicy::default())
            .expect("connect open-loop client");
    let mut x = Mat::zeros(1, queries.cols);
    let mut lat_ms = Vec::with_capacity(n_open);
    let start = Instant::now();
    for i in 0..n_open {
        let offset = i as f64 / rate;
        let target = Duration::from_secs_f64(offset);
        let now = start.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        x.row_mut(0).copy_from_slice(queries.row(i % queries.rows));
        client.query(&x, K, nprobe, i as u64).expect("open-loop query");
        lat_ms.push((start.elapsed().as_secs_f64() - offset) * 1e3);
    }
    let achieved = n_open as f64 / start.elapsed().as_secs_f64();
    let (op50, op99) =
        (percentile(&lat_ms, 50.0), percentile(&lat_ms, 99.0));
    println!(
        "  open loop @ {rate:.0} qps: achieved {achieved:.0} qps  \
         p50 {op50:.3} ms  p99 {op99:.3} ms"
    );
    handle.shutdown();

    // the shared metrics::write_bench_json guard refuses non-finite
    // payloads below, covering every number assembled here
    let out = Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("quick", Json::Bool(quick)),
        ("gallery", Json::Num(n_gallery as f64)),
        ("dim", Json::Num(spec.dim as f64)),
        ("kproj", Json::Num(kproj as f64)),
        ("k", Json::Num(K as f64)),
        ("nclusters", Json::Num(NCLUSTERS as f64)),
        ("nprobe_default", Json::Num(nprobe as f64)),
        ("recall_at_k", Json::Num(recall)),
        ("closed_loop", Json::Arr(closed)),
        ("open_loop", Json::obj(vec![
            ("rate_qps", Json::Num(rate)),
            ("achieved_qps", Json::Num(achieved)),
            ("p50_ms", Json::Num(op50)),
            ("p99_ms", Json::Num(op99)),
        ])),
    ]);
    match dmlps::metrics::write_bench_json("BENCH_serving.json", &out) {
        Ok(path) => println!(
            "\nwrote machine-readable baseline to {}",
            path.display()
        ),
        Err(e) => {
            eprintln!("ERROR: {e}");
            std::process::exit(1);
        }
    }
}
