//! Fig 4(c) — precision-recall on ImageNet-1M: Euclidean distance on raw
//! features vs the learned Mahalanobis metric.
//!
//! Uses the imnet1m preset (LLC-like sparse features, dimension-scaled
//! per DESIGN.md), trains with the distributed path's configuration
//! single-threaded, and prints both PR curves on held-out pairs.
//! Expected shape: "with distance metric learning, the performance is
//! greatly improved" — the learned curve dominates Euclidean everywhere.

use std::sync::Arc;

use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::dml::NativeEngine;
use dmlps::eval::{average_precision, pr_curve, score_pairs,
                  score_pairs_euclidean};
use dmlps::session::Session;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let mut cfg = Preset::Imnet1mScaled.config();
    cfg.optim.steps = if quick { 30 } else { 150 };
    println!(
        "# Fig 4(c): PR curves on ImageNet-1M analog (d={} k={}, \
         LLC-like features)\n",
        cfg.dataset.dim, cfg.model.k
    );
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));

    let steps = cfg.optim.steps;
    let run = Session::from_config(cfg)
        .data(data.clone())
        .probe(50, (500, 500))
        .train_sequential()?;
    println!(
        "trained {} steps in {:.1}s (objective {:.4} → {:.4})\n",
        steps, run.wall_s,
        run.curve.points.first().unwrap().objective,
        run.curve.points.last().unwrap().objective
    );

    let mut engine = NativeEngine::new();
    let (sim_l, dis_l) = score_pairs(
        &mut engine, run.l()?, &data.test, &data.test_pairs,
    )?;
    let (sim_e, dis_e) =
        score_pairs_euclidean(&data.test, &data.test_pairs);

    let grid: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
    let sample = |sim: &[f32], dis: &[f32]| -> Vec<f64> {
        let curve = pr_curve(sim, dis);
        grid.iter()
            .map(|&r| {
                curve
                    .iter()
                    .find(|pt| pt.recall >= r)
                    .map(|pt| pt.precision)
                    .unwrap_or(f64::NAN)
            })
            .collect()
    };
    let pl = sample(&sim_l, &dis_l);
    let pe = sample(&sim_e, &dis_e);
    println!("| recall | Euclidean | learned metric |");
    println!("|---|---|---|");
    for i in 0..grid.len() {
        println!("| {:.1} | {:.4} | {:.4} |", grid[i], pe[i], pl[i]);
    }
    let ap_l = average_precision(&sim_l, &dis_l);
    let ap_e = average_precision(&sim_e, &dis_e);
    println!("\nAP: Euclidean {ap_e:.4} → learned {ap_l:.4}");
    if !quick && ap_l <= ap_e {
        println!("NOTE: expected learned > Euclidean (paper Fig 4c)");
    }
    Ok(())
}
