//! Ablation — consistency models on the real threaded parameter server:
//! ASP (the paper's choice) vs BSP (Hadoop/Spark-style barriers) vs
//! SSP(4) (bounded staleness).
//!
//! Measures wall time, time the computing threads spent blocked on the
//! consistency gate, and final objective / test AP at equal step budget.
//! Expected shape (paper §1/§2): ASP never waits, BSP pays barrier time;
//! all three reach comparable quality at this scale.

use std::sync::Arc;

use dmlps::config::{Consistency, FeatureKind, Preset};
use dmlps::data::ExperimentData;
use dmlps::eval::{ap_euclidean, ap_of_l};
use dmlps::session::Session;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let mut cfg = Preset::Tiny.config();
    cfg.dataset.name = "ablation_mid".into();
    cfg.dataset.kind = FeatureKind::Gaussian;
    // dimension/batch chosen so one gradient costs ~5 ms: the paper's
    // regime (compute >> refresh latency). With near-zero compute ASP's
    // staleness explodes and it diverges at any shared lr — a real
    // effect, but not the operating point the paper reports.
    cfg.dataset.dim = 256;
    cfg.dataset.n_classes = 10;
    cfg.dataset.separation = 4.0;
    cfg.dataset.n_train = 2_000;
    cfg.dataset.n_test = 500;
    cfg.dataset.n_similar = 5_000;
    cfg.dataset.n_dissimilar = 5_000;
    cfg.dataset.n_test_pairs = 1_000;
    cfg.model.k = 64;
    cfg.optim.steps = if quick { 300 } else { 1_200 };
    cfg.optim.batch_sim = 32;
    cfg.optim.batch_dis = 32;
    cfg.optim.lr = 0.1;
    cfg.cluster.workers = 4;
    cfg.artifact_variant = None;

    println!(
        "# Ablation: consistency models (threaded PS, {} workers, \
         {} steps/worker)\n",
        cfg.cluster.workers, cfg.optim.steps
    );
    println!(
        "| consistency | wall (s) | applied | worker wait (s) | \
         final f | test AP |"
    );
    println!("|---|---|---|---|---|---|");
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let ap_eu = ap_euclidean(&data);
    for consistency in [
        Consistency::Asp,
        Consistency::Ssp { staleness: 4 },
        Consistency::Bsp,
    ] {
        let mut c = cfg.clone();
        c.cluster.consistency = consistency;
        let r = Session::from_config(c)
            .engine("native")
            .data(data.clone())
            .train_distributed()?;
        let wait: f64 = r.worker_stats.iter().map(|w| w.wait_s).sum();

        let mut eng = dmlps::dml::NativeEngine::new();
        let ap = ap_of_l(&mut eng, r.l()?, &data)?;
        println!(
            "| {consistency} | {:.2} | {} | {:.2} | {:.4} | {:.4} |",
            r.wall_s,
            r.applied_updates,
            wait,
            r.curve.final_objective().unwrap_or(f64::NAN),
            ap
        );
    }
    println!("\nEuclidean baseline AP: {ap_eu:.4}");
    Ok(())
}
