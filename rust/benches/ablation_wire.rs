//! Ablation: PS wire compression (`cluster.compression` knob).
//!
//! Trains the paper's MNIST shape (k=600, d=780 → 1.87 MB of f32
//! parameters per full message) with the real threaded server under
//! every compression mode and records the wire profile next to the
//! fidelity it buys: encoded gradient bytes per step, the compression
//! ratio against the dense `mode=none` anchor, applied-updates/s, and
//! the objective after the fixed step budget — ratio and loss in one
//! table, so a fidelity regression can't hide behind a byte win.
//! Writes the machine-readable baseline to **`BENCH_wire.json`**
//! (override the path with `DMLPS_BENCH_OUT`).
//!
//! Byte accounting is *encoded payload per physical slice message*
//! (control/`Done` messages excluded) — the same contract as
//! `BENCH_ps.json`, so the two baselines compare directly.

use std::sync::Arc;

use dmlps::config::{CompressionConfig, CompressionMode, Preset};
use dmlps::data::ExperimentData;
use dmlps::ps::RunOptions;
use dmlps::session::Session;
use dmlps::util::json::Json;

fn main() {
    let quick = std::env::var("DMLPS_BENCH_QUICK").is_ok();
    let mut cfg = Preset::Mnist.config();
    // Keep the paper-true k×d message shape; shrink the data volume so
    // the bench measures the wire, not data generation.
    cfg.dataset.n_train = 6_000;
    cfg.dataset.n_test = 500;
    cfg.dataset.n_similar = 20_000;
    cfg.dataset.n_dissimilar = 20_000;
    cfg.dataset.n_test_pairs = 1_000;
    cfg.optim.steps = if quick { 8 } else { 30 };
    cfg.cluster.workers = 2;
    cfg.cluster.server_shards = 2;
    cfg.artifact_variant = None;
    let keep = 0.25f32;

    let dense_step_bytes = (cfg.model.k * cfg.dataset.dim * 4) as f64;
    println!(
        "ablation_wire: MNIST shape d={} k={} ({} params, {:.2} MB \
         dense per step), {} workers × {} steps, {} shards, keep={keep}",
        cfg.dataset.dim,
        cfg.model.k,
        cfg.model.k * cfg.dataset.dim,
        dense_step_bytes / 1e6,
        cfg.cluster.workers,
        cfg.optim.steps,
        cfg.cluster.server_shards,
    );
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let opts = RunOptions {
        // probe only at the endpoints: the last curve point is the
        // loss-after-N-steps fidelity figure
        probe_every: u64::MAX / 2,
        probe_pairs: (50, 50),
        ..Default::default()
    };

    println!(
        "\n| mode | grad B/step | ratio | param B/msg | applied | \
         upd/s | final obj | wall s |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    let mut rows: Vec<Json> = Vec::new();
    let mut dense_measured = 0.0f64;
    for mode in [CompressionMode::None, CompressionMode::Int8,
                 CompressionMode::TopK, CompressionMode::TopKInt8] {
        let mut c = cfg.clone();
        c.cluster.compression = CompressionConfig { mode, keep };
        let r = Session::from_config(c)
            .engine("native")
            .data(data.clone())
            .run_options(opts.clone())
            .train_distributed()
            .expect("compressed training run");
        let steps_sent: u64 =
            r.worker_stats.iter().map(|w| w.grads_sent).sum();
        let grad_bytes_per_step =
            r.grad_bytes_received as f64 / steps_sent.max(1) as f64;
        if mode == CompressionMode::None {
            dense_measured = grad_bytes_per_step;
        }
        let ratio = dense_measured / grad_bytes_per_step.max(1.0);
        let param_bytes_per_msg =
            r.param_bytes_sent as f64 / r.param_msgs.max(1) as f64;
        let ups = r.applied_updates as f64 / r.wall_s.max(1e-9);
        let final_obj = r.curve.final_objective().unwrap_or(f64::NAN);
        println!(
            "| {} | {grad_bytes_per_step:.0} | {ratio:.2}x | \
             {param_bytes_per_msg:.0} | {} | {ups:.1} | \
             {final_obj:.4} | {:.2} |",
            mode.name(), r.applied_updates, r.wall_s
        );
        rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.name().into())),
            ("keep", Json::Num(keep as f64)),
            ("grad_bytes_per_step", Json::Num(grad_bytes_per_step)),
            ("grad_bytes_total",
             Json::Num(r.grad_bytes_received as f64)),
            ("compression_ratio", Json::Num(ratio)),
            ("param_bytes_per_msg", Json::Num(param_bytes_per_msg)),
            ("param_bytes_total", Json::Num(r.param_bytes_sent as f64)),
            ("param_msgs", Json::Num(r.param_msgs as f64)),
            ("applied_updates", Json::Num(r.applied_updates as f64)),
            ("updates_per_sec", Json::Num(ups)),
            ("final_objective", Json::Num(final_obj)),
            ("wall_s", Json::Num(r.wall_s)),
        ]));
    }
    println!(
        "\n(dense anchor: {dense_measured:.0} B/step = 4·k·d; \
         topk_int8 target ≥ 4× at keep={keep})"
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("ablation_wire".into())),
        ("quick", Json::Bool(quick)),
        ("shape", Json::obj(vec![
            ("k", Json::Num(cfg.model.k as f64)),
            ("d", Json::Num(cfg.dataset.dim as f64)),
            ("workers", Json::Num(cfg.cluster.workers as f64)),
            ("server_shards",
             Json::Num(cfg.cluster.server_shards as f64)),
            ("steps", Json::Num(cfg.optim.steps as f64)),
            ("keep", Json::Num(keep as f64)),
            ("dense_step_bytes", Json::Num(dense_step_bytes)),
        ])),
        ("runs", Json::Arr(rows)),
    ]);
    match dmlps::metrics::write_bench_json("BENCH_wire.json", &out) {
        Ok(path) => println!(
            "\nwrote machine-readable baseline to {}",
            path.display()
        ),
        Err(e) => {
            eprintln!("ERROR: {e}");
            std::process::exit(1);
        }
    }
}
