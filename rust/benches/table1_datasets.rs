//! Table 1 — Statistics of Datasets.
//!
//! Prints (a) the paper's exact Table 1 (derived from the paper-true
//! shapes encoded in `config::PAPER_SHAPES`) and (b) the synthetic-analog
//! configurations this repo actually runs on the 1-core testbed, with
//! the scale mapping. Regenerates the table layout of the paper.

use dmlps::config::{Preset, PAPER_SHAPES};
use dmlps::data::{DatasetStats, ExperimentData};

fn fmt_count(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{}M", n / 1_000_000)
    } else if n >= 1_000 {
        format!("{}K", n / 1_000)
    } else {
        format!("{n}")
    }
}

fn main() {
    println!("# Table 1: Statistics of Datasets\n");
    println!("## (a) paper-true shapes\n");
    println!(
        "| Dataset | feat. dim | k | # parameters | #samples | \
         #similar pairs | #dissimilar pairs |"
    );
    println!("|---|---|---|---|---|---|---|");
    for s in &PAPER_SHAPES {
        let params = s.n_params() as f64;
        let params_str = if params >= 1e9 {
            format!("{:.2}B", params / 1e9)
        } else if params >= 1e6 {
            format!("{:.3}", params / 1e6)
                .trim_end_matches('0')
                .trim_end_matches('.')
                .to_string()
                + "M"
        } else {
            format!("{:.2}M", params / 1e6)
        };
        println!(
            "| {} | {} | {} | {} | {} | {} | {} |",
            s.name,
            s.d,
            s.k,
            params_str,
            fmt_count(s.n_samples),
            fmt_count(s.n_similar),
            fmt_count(s.n_dissimilar),
        );
    }

    println!("\n## (b) synthetic analogs run in this repo\n");
    println!(
        "| Dataset | feat. dim | k | # parameters | #samples | \
         #similar | #dissimilar | note |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    for preset in Preset::all() {
        let cfg = preset.config();
        let st = DatasetStats::of(&cfg);
        let note = match preset {
            Preset::Mnist => "paper-true shape",
            Preset::Tiny => "test shape",
            _ => "dimension-scaled (see DESIGN.md)",
        };
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {} |",
            st.name, st.feat_dim, st.k, st.param_str(),
            fmt_count(st.n_samples), fmt_count(st.n_similar),
            fmt_count(st.n_dissimilar), note
        );
    }

    // generate the tiny + mnist datasets to prove the generators run at
    // the advertised sizes and pair labels are consistent
    println!("\n## (c) generation check\n");
    for preset in [Preset::Tiny, Preset::Mnist] {
        let cfg = preset.config();
        let t0 = std::time::Instant::now();
        let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
        println!(
            "{}: generated {} train / {} test samples, {}S+{}D pairs in \
             {:.2}s (labels consistent: {})",
            cfg.dataset.name,
            data.train.n(),
            data.test.n(),
            data.pairs.similar.len(),
            data.pairs.dissimilar.len(),
            t0.elapsed().as_secs_f64(),
            data.pairs.check_labels(&data.train),
        );
    }
}
